"""Recurrent sequence mixers: Mamba2 (SSD), mLSTM and sLSTM (xLSTM).

All three expose ``init_* / apply_* / *_decode`` with chunked (sub-quadratic)
training forms where the math allows:

* **Mamba2** — chunked state-space duality: quadratic *within* a chunk,
  linear state carry *across* chunks (lax.scan), exactly the SSD algorithm
  of Dao & Gu 2024 with the cross-chunk combination done as a scan instead
  of the quadratic `segsum` so 500k contexts lower cleanly.
* **mLSTM** — matrix-memory LSTM with exponential gating, in a stabilized
  chunked-parallel form: per-chunk log-space weights with a running
  max-stabilizer carried across chunks (Beck et al. 2024, §A).
* **sLSTM** — scalar-memory LSTM with hidden-state recurrence (block-
  diagonal per-head R), inherently sequential -> lax.scan over time.

Decode steps are O(1)-state recurrences; caches are dicts of arrays.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dtype_of, fan_in_init


def _split_sizes(x, sizes, axis=-1):
    out, start = [], 0
    for s in sizes:
        out.append(jax.lax.slice_in_dim(x, start, start + s, axis=axis))
        start += s
    return out


def _gated_rmsnorm(y, z, scale, eps):
    """Mamba2-style output norm: RMSNorm(y * silu(z)) * scale."""
    g = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    var = jnp.mean(jnp.square(g.astype(jnp.float32)), -1, keepdims=True)
    return (g.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
            * scale).astype(y.dtype)


# ===================================================================== SSD

def _mamba_dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    conv_ch = d_inner + 2 * s.n_groups * s.state_dim
    return d_inner, n_heads, conv_ch


def init_mamba2(key, cfg: ModelConfig) -> dict:
    """Projections are split per segment (z / x / B / C / dt) instead of
    one fused ``w_in`` so tensor-parallel sharding never crosses segment
    boundaries (x shards on heads, B/C/dt stay replicated — they're
    small)."""
    s = cfg.ssm
    d = cfg.d_model
    d_inner, nh, _ = _mamba_dims(cfg)
    gn = s.n_groups * s.state_dim
    pd = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 7)
    return {
        "w_z": fan_in_init(ks[0], (d, d_inner), d, pd),
        "w_x": fan_in_init(ks[1], (d, d_inner), d, pd),
        "w_bc": fan_in_init(ks[2], (d, 2 * gn), d, pd),
        "w_dt": fan_in_init(ks[3], (d, nh), d, pd),
        "conv_x_w": fan_in_init(ks[4], (s.conv_width, d_inner),
                                s.conv_width, pd),
        "conv_x_b": jnp.zeros((d_inner,), pd),
        "conv_bc_w": fan_in_init(ks[5], (s.conv_width, 2 * gn),
                                 s.conv_width, pd),
        "conv_bc_b": jnp.zeros((2 * gn,), pd),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32)),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm_scale": jnp.ones((d_inner,), jnp.float32),
        "w_out": fan_in_init(ks[6], (d_inner, d), d_inner, pd),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv: x [B,S,C], w [W,C] -> [B,S,C]."""
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(width):
        out = out + xp[:, i:i + x.shape[1], :] * w[i]
    return out + b


def _mamba_project(p, u, cfg: ModelConfig):
    """Returns z [B,S,d_inner], x_pre [B,S,d_inner], bc_pre [B,S,2GN],
    dt_pre [B,S,H] (pre-conv, pre-activation)."""
    cd = dtype_of(cfg.compute_dtype)
    uc = u.astype(cd)
    z = uc @ p["w_z"].astype(cd)
    x_pre = uc @ p["w_x"].astype(cd)
    bc_pre = uc @ p["w_bc"].astype(cd)
    dt_pre = uc @ p["w_dt"].astype(cd)
    return z, x_pre, bc_pre, dt_pre


def _mamba_split_bc(bc, cfg: ModelConfig):
    s = cfg.ssm
    d_inner, nh, _ = _mamba_dims(cfg)
    gn = s.n_groups * s.state_dim
    bb, cc = _split_sizes(bc, [gn, gn])
    b, sl = bb.shape[0], bb.shape[1]
    rep = nh // s.n_groups
    bb = jnp.repeat(bb.reshape(b, sl, s.n_groups, s.state_dim), rep, axis=2)
    cc = jnp.repeat(cc.reshape(b, sl, s.n_groups, s.state_dim), rep, axis=2)
    return bb, cc


def apply_mamba2(p, u, cfg: ModelConfig) -> jax.Array:
    """Chunked SSD forward.  u [B,S,D] -> [B,S,D].  S % chunk == 0."""
    s = cfg.ssm
    cd = dtype_of(cfg.compute_dtype)
    bsz, slen, _ = u.shape
    d_inner, nh, _ = _mamba_dims(cfg)
    q = min(s.chunk, slen)
    if slen % q:
        raise ValueError(f"seq {slen} not divisible by ssm chunk {q}")
    nc = slen // q

    z, x_pre, bc_pre, dt_pre = _mamba_project(p, u, cfg)
    x = jax.nn.silu(_causal_conv(x_pre, p["conv_x_w"].astype(cd),
                                 p["conv_x_b"].astype(cd)))
    x = x.reshape(bsz, slen, nh, s.head_dim)
    bc = jax.nn.silu(_causal_conv(bc_pre, p["conv_bc_w"].astype(cd),
                                  p["conv_bc_b"].astype(cd)))
    bb, cc = _mamba_split_bc(bc, cfg)
    dt = jax.nn.softplus(dt_pre.astype(jnp.float32) + p["dt_bias"])
    da = -jnp.exp(p["a_log"]) * dt                                # <= 0
    xdt = x.astype(jnp.float32) * dt[..., None]

    # chunk fold: [B, S, ...] -> [nc, B, q, ...] for scan
    def fold(t):
        return t.reshape(bsz, nc, q, *t.shape[2:]).swapaxes(0, 1)

    xdt_c, b_c, c_c, da_c = map(fold, (xdt, bb.astype(jnp.float32),
                                       cc.astype(jnp.float32), da))

    def chunk_step(state, inp):
        xdt_i, b_i, c_i, da_i = inp            # [B,q,...]
        cum = jnp.cumsum(da_i, axis=1)         # [B,q,H]
        # intra-chunk (masked quadratic); mask BEFORE exp so the masked
        # upper triangle (positive args -> inf) can't poison gradients
        rel = cum[:, :, None, :] - cum[:, None, :, :]      # [B,q,q,H] i-j
        mask = jnp.tril(jnp.ones((q, q), bool))
        rel = jnp.where(mask[None, :, :, None], rel, -1e30)
        l_w = jnp.exp(rel)
        sc = jnp.einsum("bihn,bjhn->bijh", c_i, b_i) * l_w
        y = jnp.einsum("bijh,bjhp->bihp", sc, xdt_i)
        # inter-chunk (state from previous chunks)
        y = y + jnp.einsum("bihn,bhpn,bih->bihp", c_i, state,
                           jnp.exp(cum))
        # state update for next chunk
        decay_out = jnp.exp(cum[:, -1:, :] - cum)          # [B,q,H]
        new_state = state * jnp.exp(cum[:, -1])[..., None, None] + \
            jnp.einsum("bjhn,bjhp,bjh->bhpn", b_i, xdt_i, decay_out)
        return new_state, y

    init = jnp.zeros((bsz, nh, s.head_dim, s.state_dim), jnp.float32)
    _, ys = jax.lax.scan(chunk_step, init, (xdt_c, b_c, c_c, da_c))
    y = ys.swapaxes(0, 1).reshape(bsz, slen, nh, s.head_dim)
    y = y + p["d_skip"][:, None] * x.astype(jnp.float32)
    y = y.reshape(bsz, slen, d_inner).astype(cd)
    y = _gated_rmsnorm(y, z, p["norm_scale"], cfg.norm_eps)
    return y @ p["w_out"].astype(cd)


def init_mamba2_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    s = cfg.ssm
    d_inner, nh, _ = _mamba_dims(cfg)
    gn = s.n_groups * s.state_dim
    return {
        "conv_x": jnp.zeros((batch, s.conv_width - 1, d_inner), dtype),
        "conv_bc": jnp.zeros((batch, s.conv_width - 1, 2 * gn), dtype),
        "state": jnp.zeros((batch, nh, s.head_dim, s.state_dim),
                           jnp.float32),
    }


def mamba2_decode(p, u, cache: dict, cfg: ModelConfig):
    """One-token recurrent step.  u [B,1,D]."""
    s = cfg.ssm
    cd = dtype_of(cfg.compute_dtype)
    bsz = u.shape[0]
    d_inner, nh, _ = _mamba_dims(cfg)
    z, x_pre, bc_pre, dt_pre = _mamba_project(p, u, cfg)
    win_x = jnp.concatenate([cache["conv_x"].astype(cd), x_pre], axis=1)
    win_bc = jnp.concatenate([cache["conv_bc"].astype(cd), bc_pre], axis=1)
    x_t = jax.nn.silu(
        jnp.einsum("bwc,wc->bc", win_x, p["conv_x_w"].astype(cd))
        + p["conv_x_b"].astype(cd))
    bc_t = jax.nn.silu(
        jnp.einsum("bwc,wc->bc", win_bc, p["conv_bc_w"].astype(cd))
        + p["conv_bc_b"].astype(cd))
    x = x_t.reshape(bsz, nh, s.head_dim)
    bb, cc = _mamba_split_bc(bc_t[:, None, :], cfg)
    bb, cc = bb[:, 0], cc[:, 0]                        # [B,H,N]
    dt_t = jax.nn.softplus(dt_pre[:, 0].astype(jnp.float32) + p["dt_bias"])
    decay = jnp.exp(-jnp.exp(p["a_log"]) * dt_t)      # [B,H]
    state = cache["state"] * decay[..., None, None] + jnp.einsum(
        "bhp,bhn->bhpn", x.astype(jnp.float32) * dt_t[..., None],
        bb.astype(jnp.float32))
    y = jnp.einsum("bhpn,bhn->bhp", state, cc.astype(jnp.float32))
    y = y + p["d_skip"][:, None] * x.astype(jnp.float32)
    y = y.reshape(bsz, 1, d_inner).astype(cd)
    y = _gated_rmsnorm(y, z, p["norm_scale"], cfg.norm_eps)
    new_cache = {"conv_x": win_x[:, 1:, :].astype(cache["conv_x"].dtype),
                 "conv_bc": win_bc[:, 1:, :].astype(cache["conv_bc"].dtype),
                 "state": state}
    return y @ p["w_out"].astype(cd), new_cache


# =================================================================== mLSTM

def _mlstm_dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    nh = d_inner // s.head_dim
    return d_inner, nh


def init_mlstm(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    d_inner, nh = _mlstm_dims(cfg)
    pd = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    return {
        "w_mq": fan_in_init(ks[0], (d, d_inner), d, pd),
        "w_mk": fan_in_init(ks[1], (d, d_inner), d, pd),
        "w_mv": fan_in_init(ks[2], (d, d_inner), d, pd),
        "w_gates": fan_in_init(ks[3], (d, 2 * nh), d, pd),  # i, f pre-acts
        "w_ogate": fan_in_init(ks[4], (d, d_inner), d, pd),
        "norm_scale": jnp.ones((d_inner,), jnp.float32),
        "w_out": fan_in_init(ks[5], (d_inner, d), d_inner, pd),
        "f_bias": 3.0 * jnp.ones((nh,), jnp.float32),   # open forget gates
    }


def _mlstm_qkv(p, u, cfg: ModelConfig):
    s = cfg.ssm
    cd = dtype_of(cfg.compute_dtype)
    b, sl, _ = u.shape
    d_inner, nh = _mlstm_dims(cfg)
    uc = u.astype(cd)
    q = (uc @ p["w_mq"].astype(cd)).reshape(b, sl, nh, s.head_dim)
    k = (uc @ p["w_mk"].astype(cd)).reshape(b, sl, nh, s.head_dim)
    v = (uc @ p["w_mv"].astype(cd)).reshape(b, sl, nh, s.head_dim)
    gates = (uc @ p["w_gates"].astype(cd)).astype(jnp.float32)
    i_pre, f_pre = gates[..., :nh], gates[..., nh:]
    logf = jax.nn.log_sigmoid(f_pre + p["f_bias"])
    k = k / jnp.sqrt(jnp.asarray(s.head_dim, cd))
    return q, k, v, i_pre, logf


def apply_mlstm(p, u, cfg: ModelConfig) -> jax.Array:
    """Stabilized chunked-parallel mLSTM.  u [B,S,D] -> [B,S,D]."""
    s = cfg.ssm
    cd = dtype_of(cfg.compute_dtype)
    bsz, slen, _ = u.shape
    d_inner, nh = _mlstm_dims(cfg)
    qq = min(s.chunk, slen)
    if slen % qq:
        raise ValueError(f"seq {slen} not divisible by ssm chunk {qq}")
    nc = slen // qq
    q, k, v, i_pre, logf = _mlstm_qkv(p, u, cfg)

    def fold(t):
        return t.reshape(bsz, nc, qq, *t.shape[2:]).swapaxes(0, 1)

    q_c, k_c, v_c, i_c, f_c = map(fold, (
        q.astype(jnp.float32), k.astype(jnp.float32),
        v.astype(jnp.float32), i_pre, logf))

    p_dim = s.head_dim

    def chunk_step(carry, inp):
        c_mat, n_vec, m_run = carry        # [B,H,P,P], [B,H,P], [B,H]
        qi, ki, vi, ii, fi = inp           # [B,q,...]
        f_cum = jnp.cumsum(fi, axis=1)                  # F_t  [B,q,H]
        b_log = ii - f_cum                              # b_j = i_j - F_j
        g = jnp.maximum(jax.lax.cummax(b_log, axis=1),
                        m_run[:, None, :])              # [B,q,H]
        m_i = f_cum + g                                 # stabilizer per pos
        # intra-chunk weights w_ij = exp(F_i + b_j - m_i), j <= i; mask
        # the argument before exp (inf * 0 = NaN in the cotangent)
        w_arg = b_log[:, None, :, :] - g[:, :, None, :]        # [B,i,j,H]
        mask = jnp.tril(jnp.ones((qq, qq), bool))
        w = jnp.exp(jnp.where(mask[None, :, :, None], w_arg, -1e30))
        qk = jnp.einsum("bihp,bjhp->bijh", qi, ki)
        num = jnp.einsum("bijh,bijh,bjhp->bihp", qk, w, vi)
        # inter-chunk contribution with factor exp(m_prev - g_i)
        inter_w = jnp.exp(m_run[:, None, :] - g)        # [B,q,H]
        num = num + jnp.einsum("bihr,bhpr,bih->bihp", qi, c_mat, inter_w)
        # denominator: n_i = sum_j w_ij k_j + inter_w * n_prev
        n_i = jnp.einsum("bijh,bjhp->bihp", w, ki) + \
            inter_w[..., None] * n_vec[:, None, :, :]
        dot = jnp.einsum("bihp,bihp->bih", qi, n_i)
        den = jnp.maximum(jnp.abs(dot), jnp.exp(-m_i))
        y = num / den[..., None]
        # carry update at chunk end
        g_end = g[:, -1, :]
        m_new = f_cum[:, -1, :] + g_end
        w_end = jnp.exp(b_log - g_end[:, None, :])      # [B,j,H]
        c_new = jnp.exp(m_run - g_end)[..., None, None] * c_mat + \
            jnp.einsum("bjh,bjhp,bjhr->bhpr", w_end, vi, ki)
        n_new = jnp.exp(m_run - g_end)[..., None] * n_vec + \
            jnp.einsum("bjh,bjhp->bhp", w_end, ki)
        return (c_new, n_new, m_new), y

    init = (jnp.zeros((bsz, nh, p_dim, p_dim), jnp.float32),
            jnp.zeros((bsz, nh, p_dim), jnp.float32),
            jnp.full((bsz, nh), -1e30, jnp.float32))
    _, ys = jax.lax.scan(chunk_step, init, (q_c, k_c, v_c, i_c, f_c))
    y = ys.swapaxes(0, 1).reshape(bsz, slen, d_inner).astype(cd)
    o = jax.nn.sigmoid(u.astype(cd) @ p["w_ogate"].astype(cd))
    y = _gated_rmsnorm(y, jnp.zeros_like(y) + 1.7159, p["norm_scale"],
                       cfg.norm_eps) * o
    return y @ p["w_out"].astype(cd)


def init_mlstm_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    s = cfg.ssm
    d_inner, nh = _mlstm_dims(cfg)
    return {"C": jnp.zeros((batch, nh, s.head_dim, s.head_dim),
                           jnp.float32),
            "n": jnp.zeros((batch, nh, s.head_dim), jnp.float32),
            "m": jnp.full((batch, nh), -1e30, jnp.float32)}


def mlstm_decode(p, u, cache: dict, cfg: ModelConfig):
    """One-token mLSTM recurrence (Beck et al. eqs. 19-27)."""
    cd = dtype_of(cfg.compute_dtype)
    bsz = u.shape[0]
    d_inner, nh = _mlstm_dims(cfg)
    q, k, v, i_pre, logf = _mlstm_qkv(p, u, cfg)
    q, k, v = (t[:, 0].astype(jnp.float32) for t in (q, k, v))
    i_t, f_t = i_pre[:, 0], logf[:, 0]                   # [B,H]
    m_new = jnp.maximum(f_t + cache["m"], i_t)
    f_fac = jnp.exp(f_t + cache["m"] - m_new)
    i_fac = jnp.exp(i_t - m_new)
    c_new = f_fac[..., None, None] * cache["C"] + \
        i_fac[..., None, None] * jnp.einsum("bhp,bhr->bhpr", v, k)
    n_new = f_fac[..., None] * cache["n"] + i_fac[..., None] * k
    num = jnp.einsum("bhpr,bhr->bhp", c_new, q)
    dot = jnp.einsum("bhp,bhp->bh", q, n_new)
    den = jnp.maximum(jnp.abs(dot), jnp.exp(-m_new))
    y = (num / den[..., None]).reshape(bsz, 1, d_inner).astype(cd)
    o = jax.nn.sigmoid(u.astype(cd) @ p["w_ogate"].astype(cd))
    y = _gated_rmsnorm(y, jnp.zeros_like(y) + 1.7159, p["norm_scale"],
                       cfg.norm_eps) * o
    return y @ p["w_out"].astype(cd), \
        {"C": c_new, "n": n_new, "m": m_new}


# =================================================================== sLSTM

def init_slstm(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    nh = cfg.n_heads
    dh = d // nh
    pd = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    return {
        "w_gates": fan_in_init(ks[0], (d, 4 * d), d, pd),   # z, i, f, o
        "r_gates": fan_in_init(ks[1], (nh, dh, 4 * dh), dh, pd),
        "gate_bias": jnp.concatenate(
            [jnp.zeros((2 * d,), jnp.float32),
             3.0 * jnp.ones((d,), jnp.float32),
             jnp.zeros((d,), jnp.float32)]),
        "norm_scale": jnp.ones((d,), jnp.float32),
        "w_out": fan_in_init(ks[2], (d, d), d, pd),
    }


def _slstm_step(p, x_t, state, cfg: ModelConfig):
    """x_t [B,D]; state = (h, c, n, m) each [B,D] (heads folded)."""
    d = cfg.d_model
    nh = cfg.n_heads
    dh = d // nh
    h, c, n, m = state
    bsz = x_t.shape[0]
    wx = x_t @ p["w_gates"].astype(x_t.dtype)               # [B,4D]
    hh = h.reshape(bsz, nh, dh)
    rh = jnp.einsum("bhd,hde->bhe", hh,
                    p["r_gates"].astype(x_t.dtype))         # [B,H,4dh]
    rh = rh.reshape(bsz, nh, 4, dh).swapaxes(1, 2).reshape(bsz, 4 * d)
    pre = (wx + rh).astype(jnp.float32) + p["gate_bias"]
    z_p, i_p, f_p, o_p = jnp.split(pre, 4, axis=-1)
    z = jnp.tanh(z_p)
    o = jax.nn.sigmoid(o_p)
    logf = jax.nn.log_sigmoid(f_p)
    m_new = jnp.maximum(logf + m, i_p)
    i_g = jnp.exp(i_p - m_new)
    f_g = jnp.exp(logf + m - m_new)
    c_new = f_g * c + i_g * z
    n_new = f_g * n + i_g
    h_new = o * (c_new / jnp.maximum(n_new, 1e-6))
    return (h_new.astype(x_t.dtype), c_new, n_new, m_new)


def apply_slstm(p, u, cfg: ModelConfig) -> jax.Array:
    """Sequential sLSTM over time (lax.scan).  u [B,S,D] -> [B,S,D]."""
    cd = dtype_of(cfg.compute_dtype)
    bsz, slen, d = u.shape
    uc = u.astype(cd)

    def step(state, x_t):
        new = _slstm_step(p, x_t, state, cfg)
        return new, new[0]

    init = (jnp.zeros((bsz, d), cd), jnp.zeros((bsz, d), jnp.float32),
            jnp.zeros((bsz, d), jnp.float32),
            jnp.full((bsz, d), -1e30, jnp.float32))
    _, hs = jax.lax.scan(step, init, uc.swapaxes(0, 1))
    y = hs.swapaxes(0, 1)                                   # [B,S,D]
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), -1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + cfg.norm_eps)
         * p["norm_scale"]).astype(cd)
    return y @ p["w_out"].astype(cd)


def init_slstm_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    d = cfg.d_model
    return {"h": jnp.zeros((batch, d), dtype),
            "c": jnp.zeros((batch, d), jnp.float32),
            "n": jnp.zeros((batch, d), jnp.float32),
            "m": jnp.full((batch, d), -1e30, jnp.float32)}


def slstm_decode(p, u, cache: dict, cfg: ModelConfig):
    cd = dtype_of(cfg.compute_dtype)
    state = (cache["h"].astype(cd), cache["c"], cache["n"], cache["m"])
    new = _slstm_step(p, u[:, 0].astype(cd), state, cfg)
    y = new[0][:, None, :]
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), -1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + cfg.norm_eps)
         * p["norm_scale"]).astype(cd)
    out = y @ p["w_out"].astype(cd)
    return out, {"h": new[0], "c": new[1], "n": new[2], "m": new[3]}
