"""Data pipeline: deterministic, step-indexed, restart-exact.

For LM pretraining we use a synthetic token stream (no corpora ship in
this container): a seeded Zipfian token sampler with injected n-gram
structure so the loss actually decreases.  The pipeline is *stateless by
construction* — batch ``i`` is a pure function of ``(seed, i)`` — which
makes checkpoint/restart exact (fault tolerance needs no data-state file)
and lets any host materialize only its shard (host-sharded loading).

A background-thread prefetcher overlaps host batch synthesis with device
steps.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 1234
    zipf_alpha: float = 1.1
    ngram_order: int = 3
    ngram_prob: float = 0.6     # P(continue an n-gram template)
    n_templates: int = 2048


def _rng_for(seed: int, step: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([seed, step]))


def synth_batch(cfg: ModelConfig, dcfg: DataConfig, step: int,
                batch: int, seq: int,
                host_slice: Optional[slice] = None) -> Dict[str, np.ndarray]:
    """Batch ``step`` of the synthetic stream (pure function of inputs).

    ``host_slice`` selects this host's rows of the global batch."""
    rng = _rng_for(dcfg.seed, step)
    v = cfg.vocab_size
    # Zipfian unigram base
    ranks = np.arange(1, v + 1, dtype=np.float64)
    probs = ranks ** -dcfg.zipf_alpha
    probs /= probs.sum()
    tokens = rng.choice(v, size=(batch, seq), p=probs).astype(np.int32)
    # overlay n-gram templates (learnable structure)
    tpl_rng = _rng_for(dcfg.seed, 0x7EA11A7E)    # templates fixed per seed
    templates = tpl_rng.integers(0, v, size=(dcfg.n_templates,
                                             dcfg.ngram_order))
    starts = rng.random((batch, seq)) < dcfg.ngram_prob / dcfg.ngram_order
    tpl_ids = rng.integers(0, dcfg.n_templates, size=(batch, seq))
    for k in range(dcfg.ngram_order):
        mask = np.zeros((batch, seq), bool)
        mask[:, k:] = starts[:, :seq - k]
        ids = np.roll(tpl_ids, k, axis=1)
        tokens[mask] = templates[ids[mask], k]
    out = {"tokens": tokens}
    if cfg.vision_tokens:
        out["vision_embeds"] = rng.standard_normal(
            (batch, cfg.vision_tokens, cfg.vision_dim)).astype(np.float32)
    if cfg.is_encoder_decoder:
        out["audio_frames"] = rng.standard_normal(
            (batch, cfg.encoder_seq, cfg.d_model)).astype(np.float32)
    if host_slice is not None:
        out = {k: x[host_slice] for k, x in out.items()}
    return out


class Prefetcher:
    """Background-thread prefetch of synth batches (overlaps with steps)."""

    def __init__(self, cfg: ModelConfig, dcfg: DataConfig, batch: int,
                 seq: int, start_step: int = 0, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()

        def worker():
            step = start_step
            while not self._stop.is_set():
                b = synth_batch(cfg, dcfg, step, batch, seq)
                while not self._stop.is_set():
                    try:
                        self._q.put((step, b), timeout=0.1)
                        break
                    except queue.Full:
                        continue
                step += 1

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def __iter__(self) -> Iterator:
        while True:
            yield self._q.get()

    def close(self):
        self._stop.set()
