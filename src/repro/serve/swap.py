"""Zero-downtime hot-swap: snapshot → canary → promote / rollback.

ISSUE 7's orchestration layer.  The pieces live elsewhere — versioned
pools (``serve/replica.py``), canary dispatch + atomic ``install_pool``
(``serve/engine.py``), digest-verified snapshots
(``distributed/checkpoint.py``), the incremental trainer
(``train/online.py``) — this module wires them into the deployment
story:

  swapper = HotSwapper(engine, ckpt_dir)
  swapper.begin(trained.ta_state, key)   # snapshot the serving pool,
                                         # build the candidate pool in
                                         # FULL, arm one chip of it as
                                         # the canary
  ... keep pumping the engine: a deterministic fraction of live
      batches serve from the canary, shadow-scored against the stable
      pool in ServeMetrics ...
  if swapper.decision() == "promote": swapper.promote()
  else:                               swapper.rollback()

Two invariants the tests hold this module to:

* **bit-equality on promote** — ``begin`` builds the ENTIRE candidate
  pool up front (the canary chip is a slice of it, not a separate
  programming), with the same key-split discipline as
  ``ServeEngine.from_ta_state``.  ``promote`` installs that pre-built
  pool, so the promoted engine's predictions are bit-identical to a
  fresh engine built from the same TA state and key.
* **bit-equality on rollback** — ``begin`` snapshots the serving pool
  through ``distributed/checkpoint.py`` (sha256 content digest in the
  manifest); ``rollback`` restores it with digest verification and
  re-installs, so the rolled-back pool is bit-for-bit the pre-swap
  pool — never a re-programmed approximation of it.

``hot_swap`` is the one-call variant (no canary): snapshot, re-program,
install.  Everything here is between-dispatch atomic and drops nothing:
in-flight batches complete at their issue-time version, queued requests
serve post-swap at the new one, and streaming sessions ride through
with zero dropped windows (``tests/test_swap.py``).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import tm
from repro.distributed import checkpoint
from repro.serve.engine import ServeEngine
from repro.serve.replica import CoalescedPool, ReplicaPool

# Manifest-extra keys for pool snapshots.  ``version`` is pytree
# aux_data (deliberately — see ReplicaPool), so the checkpoint tree
# holds only the array leaves and the version travels in the manifest.
POOL_VERSION_KEY = "pool_version"
POOL_KIND_KEY = "pool_kind"


def _pool_leaves(pool) -> dict:
    """The pool's array leaves as a plain checkpoint tree."""
    if isinstance(pool, ReplicaPool):
        return {"r_stack": pool.r_stack, "include": pool.include}
    return {"ta_state": pool.ta_state, "weights": pool.weights}


def snapshot_pool(pool, ckpt_dir: str, *, keep: int = 8) -> str:
    """Save ``pool`` (leaves + version + digest) under ``ckpt_dir``.

    The checkpoint step IS the pool version, so a rollback addresses its
    restore point by the version it wants back."""
    kind = "replica" if isinstance(pool, ReplicaPool) else "coalesced"
    return checkpoint.save(
        ckpt_dir, pool.version, _pool_leaves(pool),
        extra={POOL_VERSION_KEY: int(pool.version), POOL_KIND_KEY: kind},
        keep=keep)


def restore_pool(like_pool, ckpt_dir: str, version: int):
    """The pool saved at ``version``, digest-verified, rebuilt with the
    static configs of ``like_pool`` (configs are aux_data and must match
    the serving engine anyway — ``install_pool`` re-validates)."""
    tree, manifest = checkpoint.restore(ckpt_dir, version,
                                        _pool_leaves(like_pool))
    extra = manifest.get("extra", {})
    saved_version = int(extra.get(POOL_VERSION_KEY, version))
    # Snapshots hold only the clean model leaves, so a restored pool is
    # healthy hardware by construction: any fault overlay ``like_pool``
    # carries must not leak into it.
    if isinstance(like_pool, ReplicaPool):
        return dataclasses.replace(
            like_pool, r_stack=tree["r_stack"],
            include=jnp.asarray(tree["include"], bool),
            version=saved_version, fault_mask=None)
    return dataclasses.replace(
        like_pool, ta_state=tree["ta_state"], weights=tree["weights"],
        version=saved_version, fault_mask=None)


def reprogrammed_pool(engine: ServeEngine, ta_state: jax.Array,
                      key: Optional[jax.Array] = None, *,
                      weights: Optional[jax.Array] = None):
    """The engine's pool re-programmed from freshly trained ``ta_state``.

    Key discipline mirrors ``ServeEngine.from_ta_state`` (program key =
    first half of the split), so the re-programmed pool is bit-identical
    to the pool a FRESH engine would program from the same state and
    key — the hot-swap bit-equality bar."""
    pool = engine.pool
    if isinstance(pool, CoalescedPool):
        if weights is None:
            raise ValueError("a coalesced pool re-programs from "
                             "(ta_state, weights); pass weights=")
        return pool.reprogram(ta_state, weights)
    key = key if key is not None else jax.random.PRNGKey(0)
    k_prog, _ = jax.random.split(key)
    include = tm.include_mask(jnp.asarray(ta_state), engine.tm_cfg)
    return pool.reprogram(include, k_prog)


def hot_swap(engine: ServeEngine, ta_state: jax.Array,
             key: Optional[jax.Array] = None, *,
             weights: Optional[jax.Array] = None,
             ckpt_dir: Optional[str] = None) -> int:
    """One-call swap (no canary): optionally snapshot the serving pool,
    re-program from ``ta_state``, install atomically.  Returns the new
    pool version.  Use :class:`HotSwapper` when traffic should gate the
    promotion."""
    if ckpt_dir is not None:
        snapshot_pool(engine.pool, ckpt_dir)
    pool = reprogrammed_pool(engine, ta_state, key, weights=weights)
    engine.install_pool(pool, kind="swap")
    return engine.version


@dataclasses.dataclass(frozen=True)
class SwapConfig:
    """Canary rollout policy."""

    canary_fraction: float = 0.25   # share of live batches the canary
                                    # serves while armed
    min_canary_rows: int = 64       # evidence floor before a decision
    min_agreement: float = 0.9      # promote iff canary-vs-stable argmax
                                    # agreement >= this
    keep_snapshots: int = 8         # checkpoint GC depth (rollback window)

    def __post_init__(self):
        if not (0.0 < self.canary_fraction <= 1.0):
            raise ValueError(f"canary_fraction must be in (0, 1], got "
                             f"{self.canary_fraction}")
        if not (0.0 <= self.min_agreement <= 1.0):
            raise ValueError(f"min_agreement must be in [0, 1], got "
                             f"{self.min_agreement}")
        if self.min_canary_rows < 1:
            raise ValueError(f"min_canary_rows must be >= 1, got "
                             f"{self.min_canary_rows}")


class HotSwapper:
    """Snapshot → canary → promote/rollback over one live engine.

    One rollout at a time: :meth:`begin` arms it, live traffic produces
    the agreement evidence, :meth:`promote` / :meth:`rollback` settle it.
    The swapper only reads engine metrics and calls the engine's public
    swap API — it owns no dispatch state, so it composes with sync,
    async, and streaming serving unchanged."""

    def __init__(self, engine: ServeEngine, ckpt_dir: str,
                 scfg: SwapConfig = SwapConfig()):
        self.engine = engine
        self.ckpt_dir = ckpt_dir
        self.scfg = scfg
        self.candidate = None           # pre-built candidate pool
        self._snapshot_version: Optional[int] = None
        self._rows0 = 0                 # canary tallies at begin(), so
        self._agree0 = 0                # agreement scores THIS rollout

    @property
    def active(self) -> bool:
        return self.candidate is not None

    def begin(self, ta_state: jax.Array,
              key: Optional[jax.Array] = None, *,
              weights: Optional[jax.Array] = None) -> int:
        """Snapshot the serving pool, build the full candidate pool, arm
        one chip of it as the canary.  Returns the candidate version."""
        if self.active:
            raise RuntimeError(
                "a canary rollout is already active (candidate version "
                f"{self.candidate.version}); promote or rollback first")
        snapshot_pool(self.engine.pool, self.ckpt_dir,
                      keep=self.scfg.keep_snapshots)
        self._snapshot_version = self.engine.pool.version
        self.candidate = reprogrammed_pool(self.engine, ta_state, key,
                                           weights=weights)
        # The canary chip is a SLICE of the pre-built candidate (shared
        # include plane ⇒ a half-reprogrammed pool isn't representable;
        # and promote() installing the same pre-built pool is what makes
        # promoted == fresh-built bit-equality structural).
        cand_state = self.candidate.state(self.engine.tm_cfg)
        if hasattr(cand_state, "replica_slice"):
            cand_state = cand_state.replica_slice(0)
        m = self.engine.metrics
        self._rows0, self._agree0 = m.canary_rows, m.canary_agree_rows
        self.engine.arm_canary(cand_state, self.candidate.version,
                               self.scfg.canary_fraction)
        return self.candidate.version

    # ------------------------------------------------------------ evidence

    def rows(self) -> int:
        return self.engine.metrics.canary_rows - self._rows0

    def agreement(self) -> Optional[float]:
        rows = self.rows()
        if not rows:
            return None
        agree = self.engine.metrics.canary_agree_rows - self._agree0
        return agree / rows

    def status(self) -> dict:
        return {"active": self.active,
                "candidate_version": (self.candidate.version
                                      if self.active else None),
                "stable_version": self.engine.version,
                "rows": self.rows(),
                "agreement": self.agreement(),
                "decision": self.decision()}

    def decision(self) -> str:
        """``"wait"`` until ``min_canary_rows`` of evidence, then
        ``"promote"`` or ``"rollback"`` by the agreement threshold."""
        if not self.active:
            return "idle"
        if self.rows() < self.scfg.min_canary_rows:
            return "wait"
        agreement = self.agreement()
        return ("promote" if agreement >= self.scfg.min_agreement
                else "rollback")

    # ------------------------------------------------------------- settle

    def promote(self) -> int:
        """Install the pre-built candidate pool; returns its version."""
        if not self.active:
            raise RuntimeError("no active rollout to promote")
        pool, self.candidate = self.candidate, None
        self.engine.install_pool(pool, kind="promote")
        return self.engine.version

    def rollback(self) -> int:
        """Restore the pre-swap pool bit-for-bit from its digest-verified
        snapshot and re-install it; returns its version."""
        if not self.active:
            raise RuntimeError("no active rollout to roll back")
        self.candidate = None
        self.engine.disarm_canary()
        pool = restore_pool(self.engine.pool, self.ckpt_dir,
                            self._snapshot_version)
        self.engine.install_pool(pool, kind="rollback")
        return self.engine.version


# --------------------------------------------------------------- auto-repair


@dataclasses.dataclass(frozen=True)
class RepairConfig:
    """Auto-repair policy knobs (ISSUE 8)."""

    max_attempts: int = 2       # re-program + re-probe tries per chip

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")


class RepairPolicy:
    """Closed-loop self-healing over one live engine (ISSUE 8).

    PR 7 built the repair primitives as operator-invoked tools; this
    policy closes the loop: when :meth:`~repro.serve.engine.ServeEngine.
    probe` quarantines a chip, :meth:`repair` re-programs exactly that
    replica slice (``pool.repair_replica`` — fresh D2D draws clear the
    fault overlay; the model and its version are untouched), installs it
    through the same atomic ``install_pool`` path as a hot-swap (kind
    ``"repair"``, so the audit trail shows it), re-probes, and lets the
    readmit threshold return the chip to rotation.  Nothing queued or
    in flight is dropped anywhere in the cycle — the repair install is
    between-dispatch atomic exactly like a swap.

    Like :class:`HotSwapper`, the policy owns no dispatch state: it
    composes with sync, async, and streaming serving unchanged.  Repair
    keys come from the policy's own PRNG stream so healing never
    perturbs the engine's serving noise trace.
    """

    def __init__(self, engine: ServeEngine,
                 rcfg: RepairConfig = RepairConfig(), *,
                 key: Optional[jax.Array] = None):
        self.engine = engine
        self.rcfg = rcfg
        self._key = key if key is not None else jax.random.PRNGKey(17)
        self.events: list = []          # audit trail of repair outcomes

    def _next_key(self) -> jax.Array:
        self._key, k = jax.random.split(self._key)
        return k

    def repair(self, health: Optional[dict] = None) -> dict:
        """Repair every chip that needs it; returns per-chip outcomes
        (``{replica: {"attempts", "readmitted", "health"}}``).

        Targets are the quarantined chips plus — given the latest
        ``health`` scores — any chip below the quarantine threshold that
        the last-healthy floor kept in rotation (a single-chip engine's
        only replica can break but never be quarantined; it still must
        be repaired)."""
        targets = set(self.engine.quarantined)
        if health is not None and self.engine.health is not None:
            floor = self.engine.health.hcfg.quarantine_threshold
            targets |= {i for i, h in health.items() if h < floor}
        return {i: self._repair_one(i) for i in sorted(targets)}

    def _repair_one(self, i: int) -> dict:
        hcfg = self.engine.health.hcfg if self.engine.health else None
        health = None
        for attempt in range(1, self.rcfg.max_attempts + 1):
            pool = self.engine.pool.repair_replica(i, self._next_key())
            self.engine.install_pool(pool, kind="repair")
            health = self.engine.probe()
            # Healed = back above the readmit ceiling AND out of
            # quarantine (a floor-held chip was never in it).
            if i not in self.engine.quarantined and (
                    hcfg is None or health.get(i, 0.0)
                    >= hcfg.readmit_threshold):
                break
        out = {"replica": int(i), "attempts": attempt,
               "readmitted": i not in self.engine.quarantined,
               "health": None if health is None else health.get(i)}
        self.events.append(out)
        return out

    def check(self) -> dict:
        """One self-healing tick: probe all chips, then repair whatever
        the probe found unhealthy (quarantined or floor-held).  Drive
        this from a serving loop at ``HealthConfig.probe_every_s``
        cadence (``launch/chaos.py``)."""
        health = self.engine.probe()
        repairs = self.repair(health)
        return {"health": health, "repairs": repairs}
