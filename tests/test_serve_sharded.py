"""Sharded + async serving tests on 8 forced CPU host devices.

Each test runs in a subprocess (XLA_FLAGS must be set before jax init;
the main pytest process keeps its single device) — the same pattern as
``tests/test_distributed.py``.  Covered:

* a mesh-sharded R=8 engine serves bit-identical responses to the
  single-device engine at the same seed (nominal variation), for both
  routed and ensemble modes, sync and async;
* ``pool.shard`` places the ``[R, C, L]`` stack over the ``replica``
  mesh axis and replicates the shared include plane;
* capability selection: a partitioned state requires ``CAP_SHARDED``,
  so the Pallas preference falls back LOUDLY to the GSPMD jnp path
  (same pattern as ``csa_offset``) and the engine accounts for it;
* the 1-fused-dispatch property holds under a sharded mesh (trace-count
  check mirroring the single-device 1-kernel-call stack test);
* full-noise sharded serving is bit-reproducible and equal to the
  single-device noise stream (partitionable threefry).
"""

import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# Shared subprocess prologue: a tiny training-free model served two
# ways.  48 requests over max_batch 16 gives 3 batches, so the async
# double-buffer actually pipelines.
PROLOGUE = """
    import warnings
    import numpy as np
    import jax
    import jax.numpy as jnp
    from repro import api
    from repro.core import tm
    from repro.core.tm import TMConfig
    from repro.core.variations import VariationConfig
    from repro.launch.mesh import make_replica_mesh
    from repro.serve import (AsyncServeEngine, BatcherConfig,
                             EngineConfig, ServeEngine,
                             program_replica_pool)

    assert jax.device_count() == 8, jax.device_count()
    cfg = TMConfig(n_classes=4, clauses_per_class=8, n_features=32,
                   n_states=100)
    inc = jax.random.bernoulli(jax.random.PRNGKey(0), 0.1,
                               (cfg.n_clauses, cfg.n_literals))
    ta = jnp.where(inc, cfg.n_states + 1,
                   cfg.n_states).astype(cfg.state_dtype)
    xs = np.asarray(jax.random.bernoulli(
        jax.random.PRNGKey(1), 0.4,
        (48, cfg.n_features))).astype(np.uint8)
    BCFG = BatcherConfig(max_batch=16, bucket_sizes=(8, 16))

    def engine(n_replicas, mesh=None, cls=ServeEngine, vcfg=None, **ecfg):
        return cls.from_ta_state(
            ta, cfg, n_replicas=n_replicas, key=jax.random.PRNGKey(3),
            vcfg=VariationConfig.nominal() if vcfg is None else vcfg,
            ecfg=EngineConfig(batcher=BCFG, **ecfg), mesh=mesh)

    def served(eng):
        eng.submit_many(list(xs))
        rs = eng.drain()
        return (np.array([r.pred for r in rs]),
                np.stack([r.class_sums for r in rs]))
"""


def run_devices(code: str, n: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    # Placement-independent PRNG bits: the sharded==single bitwise
    # assertions need the counter-based partitionable generator.
    env["JAX_THREEFRY_PARTITIONABLE"] = "1"
    src = textwrap.dedent(PROLOGUE) + textwrap.dedent(code)
    out = subprocess.run(
        [sys.executable, "-c", src],
        capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


def test_sharded_engine_bit_identical_to_single_device():
    """Acceptance bar: a mesh-sharded R=8 engine == the single-device
    engine bit-for-bit at the same seed and nominal variation — preds
    AND class sums, routed and ensemble, sync and async — and both
    equal the digital TM."""
    out = run_devices("""
        digital = np.asarray(tm.predict(ta, jnp.asarray(xs), cfg))
        mesh = make_replica_mesh(8, 1)
        for routing in ("round_robin", "least_loaded", "ensemble"):
            p0, s0 = served(engine(8, routing=routing))
            p1, s1 = served(engine(8, mesh=mesh, routing=routing))
            np.testing.assert_array_equal(p0, p1, err_msg=routing)
            np.testing.assert_array_equal(s0, s1, err_msg=routing)
            np.testing.assert_array_equal(p1, digital, err_msg=routing)
            p2, s2 = served(engine(8, mesh=mesh, cls=AsyncServeEngine,
                                   routing=routing))
            np.testing.assert_array_equal(p2, digital, err_msg=routing)
            np.testing.assert_array_equal(s2, s0, err_msg=routing)
        # data-parallel reads: batch axis sharded too (16 % 2 == 0)
        p3, s3 = served(engine(4, mesh=make_replica_mesh(4, 2),
                               routing="ensemble"))
        p4, s4 = served(engine(4, routing="ensemble"))
        np.testing.assert_array_equal(p3, p4)
        np.testing.assert_array_equal(s3, s4)
        print("OK sharded bitwise")
    """)
    assert "OK sharded bitwise" in out


def test_pool_shard_places_replicas_across_devices():
    out = run_devices("""
        from jax.sharding import PartitionSpec as P
        pool = program_replica_pool(inc, jax.random.PRNGKey(2), 8,
                                    VariationConfig.nominal())
        mesh = make_replica_mesh(8, 1)
        sh = pool.shard(mesh, None)
        assert sh.is_sharded and not pool.is_sharded
        assert tuple(sh.r_stack.sharding.spec) == ("replica", None, None)
        assert len(sh.r_stack.sharding.device_set) == 8
        # the shared TA actions replicate on every device
        assert sh.include.sharding.is_fully_replicated
        # programming happened before placement: same bits
        np.testing.assert_array_equal(np.asarray(sh.r_stack),
                                      np.asarray(pool.r_stack))
        # the sharded pool is still a well-behaved pytree
        sh2 = jax.tree_util.tree_map(lambda x: x, sh)
        assert sh2.n_replicas == 8 and sh2.icfg == pool.icfg
        print("OK pool shard")
    """)
    assert "OK pool shard" in out


def test_sharded_state_falls_back_loudly():
    """CAP_SHARDED gating, same pattern as csa_offset: the Pallas
    kernels don't declare it, so a sharded state rejects them with an
    inspectable reason, the engine warns at construction, and every
    dispatch is counted in ServeMetrics."""
    out = run_devices("""
        mesh = make_replica_mesh(8, 1)
        pool = program_replica_pool(inc, jax.random.PRNGKey(2), 8,
                                    VariationConfig.nominal())
        state = pool.shard(mesh, None).state(cfg).pack()
        need = api.required_capabilities(state)
        assert api.CAP_SHARDED in need
        sel = api.select_backend(state, prefer="analog-pallas-packed")
        assert sel.fell_back and sel.backend.name == "analog-jnp"
        assert "sharded_dispatch" in sel.fallback_reason
        # unsharded twin: no CAP_SHARDED requirement, no fallback
        assert api.CAP_SHARDED not in api.required_capabilities(
            pool.state(cfg))
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            eng = engine(8, mesh=mesh, backend="analog-pallas-packed")
        assert eng.selection.fell_back
        assert any("fallback" in str(x.message) for x in w)
        eng.submit_many(list(xs[:16]))
        eng.drain()
        s = eng.summary()
        assert s["sharded"] is True and s["backend"] == "analog-jnp"
        assert s["fallback_dispatches"] == eng.metrics.batches > 0
        assert any("sharded_dispatch" in r for r in s["forward_fallbacks"])
        # the mesh default preference is the jnp path: quiet by design
        eng2 = engine(8, mesh=mesh)
        assert not eng2.selection.fell_back
        assert eng2.backend.name == "analog-jnp"
        print("OK loud fallback")
    """)
    assert "OK loud fallback" in out


def test_sharded_ensemble_single_fused_dispatch():
    """The 1-fused-dispatch property survives sharding: one ensemble
    batch over the mesh traces the stacked forward exactly once (no
    per-replica or per-device Python loop), and a second batch of the
    same bucket is a pure compile-cache hit."""
    out = run_devices("""
        from repro.core import imbue
        calls = []
        real = imbue.stacked_clause_outputs
        imbue.stacked_clause_outputs = (
            lambda *a, **kw: calls.append(1) or real(*a, **kw))
        try:
            eng = engine(8, mesh=make_replica_mesh(8, 1),
                         routing="ensemble")
            eng.submit_many(list(xs[:16]))
            eng.drain()
            assert len(calls) == 1, f"{len(calls)} stacked traces"
            eng.submit_many(list(xs[16:32]))     # same bucket: cache hit
            eng.drain()
            assert len(calls) == 1, f"{len(calls)} traces after rerun"
        finally:
            imbue.stacked_clause_outputs = real
        print("OK fused dispatch", len(calls))
    """)
    assert "OK fused dispatch" in out


def test_sharded_noise_stream_matches_single_device():
    """Full noise (C2C + CSA offset -> analog-jnp on both sides): the
    sharded engine draws the SAME noise bits as the single-device one
    (partitionable threefry), so even noisy ensemble serving is
    bit-identical at a fixed seed — and reproducible run-to-run."""
    out = run_devices("""
        mesh = make_replica_mesh(8, 1)
        runs = []
        for m in (None, mesh, mesh):
            p, s = served(engine(8, mesh=m, vcfg=VariationConfig(),
                                 routing="ensemble"))
            runs.append((p, s))
        np.testing.assert_array_equal(runs[0][0], runs[1][0])
        np.testing.assert_array_equal(runs[0][1], runs[1][1])
        np.testing.assert_array_equal(runs[1][1], runs[2][1])
        print("OK noise stream")
    """)
    assert "OK noise stream" in out


def test_async_overlap_metrics_on_mesh():
    """AsyncServeEngine over a mesh: responses in submission order,
    overlap accounting within [0, 1], and the double buffer actually
    held concurrent dispatches in flight."""
    out = run_devices("""
        eng = engine(8, mesh=make_replica_mesh(8, 1),
                     cls=AsyncServeEngine)
        seen = []
        orig = eng._issue
        def spy(batch):
            seen.append(eng.in_flight)
            return orig(batch)
        eng._issue = spy
        rids = eng.submit_many(list(xs))
        rs = eng.drain()
        assert [r.rid for r in rs] == rids
        assert eng.in_flight == 0
        assert max(seen) >= 1, seen          # pipelining really happened
        s = eng.summary()
        assert 0.0 <= s["overlap_fraction"] <= 1.0
        assert s["device_wait_s"] >= 0 and s["host_pack_s"] > 0
        print("OK async mesh", max(seen))
    """)
    assert "OK async mesh" in out


def test_coalesced_sharded_engine_class_parallel():
    """Coalesced GSPMD (ISSUE 6): a CoalescedPool sharded over the
    replica mesh axis splits the [C, M] weight plane class-parallel,
    replicates the shared TA plane, requires CAP_SHARDED (so the jnp
    ``coalesced`` backend is the quiet default), and serves sums
    bit-identical to the single-device engine."""
    out = run_devices("""
        from repro.core.coalesced import CoalescedConfig
        from repro.serve import CoalescedPool

        ccfg = CoalescedConfig(n_classes=8, n_clauses=32, n_features=32,
                               n_states=100)
        k1, k2 = jax.random.split(jax.random.PRNGKey(4))
        cinc = jax.random.bernoulli(
            k1, 0.1, (ccfg.n_clauses, ccfg.n_literals))
        cta = jnp.where(cinc, ccfg.n_states + 1,
                        ccfg.n_states).astype(ccfg.state_dtype)
        w = jax.random.randint(
            k2, (ccfg.n_clauses, ccfg.n_classes), -ccfg.max_weight,
            ccfg.max_weight + 1, jnp.int32)
        mesh = make_replica_mesh(8, 1)
        pool = CoalescedPool(ta_state=cta, weights=w, cfg=ccfg)
        sh = pool.shard(mesh, None)
        assert sh.is_sharded and not pool.is_sharded
        # class-parallel: the M axis of [C, M] splits over the mesh
        assert tuple(sh.weights.sharding.spec) == (None, "replica")
        assert sh.ta_state.sharding.is_fully_replicated
        # a sharded coalesced state needs CAP_SHARDED -> jnp GSPMD path
        state = sh.state()
        assert api.CAP_SHARDED in api.required_capabilities(state)
        sel = api.select_backend(state)
        assert sel.backend.name == "coalesced" and not sel.fell_back

        def cserved(mesh_=None):
            eng = ServeEngine.from_coalesced(
                cta, w, ccfg,
                ecfg=EngineConfig(batcher=BCFG), mesh=mesh_)
            eng.submit_many(list(xs))
            rs = eng.drain()
            return (eng, np.array([r.pred for r in rs]),
                    np.stack([r.class_sums for r in rs]))

        e0, p0, s0 = cserved()
        e1, p1, s1 = cserved(mesh)
        assert e1.state.is_sharded and e1.summary()["sharded"] is True
        assert e1.backend.name == "coalesced"
        assert not e1.selection.fell_back
        assert e1.summary()["forward_fallbacks"] == []
        np.testing.assert_array_equal(s1, s0)
        np.testing.assert_array_equal(p1, p0)
        print("OK coalesced sharded")
    """)
    assert "OK coalesced sharded" in out
