"""Coalesced Tsetlin Machine (paper §V future work).

The paper closes with: "Recent works with TMs have proposed coalesced
clause architectures where clauses are shared between classes [17].
Future work aims to explore the associated trade-offs from applying the
principles of IMBUE to such an algorithm."  This module explores exactly
that (Glimsdal & Granmo 2021, arXiv:2108.07594):

* ONE pool of clauses shared by all classes; each (clause, class) pair
  carries an integer weight.  Inference: ``sums = clauses @ W``.
* Training: per example, the target class strengthens firing clauses
  (w += 1, TA Type I); a sampled negative class weakens them (w -= 1,
  TA Type II on firing clauses).

IMBUE mapping — the whole point: the crossbar is UNCHANGED (same TA
columns, same CSAs, same Boolean-to-Current path); only the digital tail
swaps polarity ±1 counters for weighted counters.  The fused Pallas
kernels already take an arbitrary [C, M] combine matrix, so
``kernels/ops.tm_class_sums``-style inference works verbatim with W.
The trade-off measured in benchmarks/ablations.py: a coalesced pool
needs ~2x fewer TA cells for the same accuracy -> proportionally less
crossbar energy (Table II economics).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.tm import literals
from repro.core.tm_train import _bernoulli_u8, _clip_state


@dataclasses.dataclass(frozen=True)
class CoalescedConfig:
    n_classes: int
    n_clauses: int              # TOTAL shared clause pool
    n_features: int
    n_states: int = 127
    threshold: int = 15
    specificity: float = 3.9
    max_weight: int = 127
    state_dtype: jnp.dtype = jnp.int16

    def __post_init__(self):
        # Fail at construction, not deep inside a kernel with an opaque
        # shape/overflow error.
        if self.n_classes < 2:
            raise ValueError(
                f"n_classes must be >= 2 (got {self.n_classes}): a "
                "coalesced pool shares clauses BETWEEN classes")
        if self.n_clauses < 1 or self.n_features < 1:
            raise ValueError(
                f"n_clauses={self.n_clauses} and n_features="
                f"{self.n_features} must both be >= 1")
        if self.max_weight < 1:
            raise ValueError(f"max_weight must be >= 1, got "
                             f"{self.max_weight}")
        info = jnp.iinfo(self.state_dtype)
        if self.max_weight > info.max:
            raise ValueError(
                f"max_weight={self.max_weight} does not fit state_dtype="
                f"{jnp.dtype(self.state_dtype).name} (max {info.max}); "
                "weight clipping would silently wrap")
        if 2 * self.n_states + 1 > info.max:
            raise ValueError(
                f"TA states span 1..{2 * self.n_states}, which does not "
                f"fit state_dtype={jnp.dtype(self.state_dtype).name} "
                f"(max {info.max})")

    @property
    def n_literals(self) -> int:
        return 2 * self.n_features

    @property
    def n_ta(self) -> int:
        return self.n_clauses * self.n_literals


def init_coalesced(key, cfg: CoalescedConfig):
    """(ta_state [C, L], weights [C, M])."""
    u = jax.random.bernoulli(key, 0.5, (cfg.n_clauses, cfg.n_literals))
    ta = (cfg.n_states + u.astype(cfg.state_dtype)).astype(cfg.state_dtype)
    w = jnp.ones((cfg.n_clauses, cfg.n_classes), jnp.int32)
    return ta, w


def clause_outputs(ta_state, lits, cfg: CoalescedConfig, *,
                   training=False):
    inc = ta_state > cfg.n_states
    viol = (1 - lits).astype(jnp.float32) @ inc.astype(jnp.float32).T
    fired = viol == 0
    if not training:
        fired = jnp.logical_and(fired, inc.any(-1)[None, :])
    return fired.astype(jnp.uint8)


def forward(ta_state, weights, x, cfg: CoalescedConfig):
    cls = clause_outputs(ta_state, literals(x), cfg)
    return cls.astype(jnp.int32) @ weights


def predict(ta_state, weights, x, cfg: CoalescedConfig):
    return jnp.argmax(forward(ta_state, weights, x, cfg), axis=-1)


def accuracy(ta_state, weights, x, y, cfg: CoalescedConfig):
    return (predict(ta_state, weights, x, cfg) == y).mean()


def _example_update(key, ta_state, weights, lits, cls, sums, y,
                    cfg: CoalescedConfig):
    """Deltas for one example: (d_state i8 [C, L], d_w i8 [C, M]).

    Vanilla-multiclass CoTM semantics: the target class pulls with prob
    (T - s_y)/2T and ONE sampled negative pushes with prob (T + s_q)/2T.
    Feedback type mirrors the weight sign for the feedback class (a
    clause whose weight opposes the class swaps Type I/II roles), which
    is how shared clauses specialize."""
    k_neg, k_sel, k_hi, k_lo = jax.random.split(key, 4)
    m = cfg.n_classes
    t = float(cfg.threshold)
    q = jax.random.randint(k_neg, (), 0, m - 1)
    q = jnp.where(q >= y, q + 1, q)
    is_tgt = jax.nn.one_hot(y, m, dtype=jnp.bool_)
    active = jnp.logical_or(is_tgt, jax.nn.one_hot(q, m, dtype=jnp.bool_))
    clipped = jnp.clip(sums.astype(jnp.float32), -t, t)
    p = jnp.where(is_tgt, (t - clipped) / (2 * t),
                  (t + clipped) / (2 * t)) * active           # [M]
    sel = jax.random.uniform(k_sel, (cfg.n_clauses, m)) < p[None, :]

    fired = cls == 1
    s = float(cfg.specificity)
    lit1 = (lits == 1)[None, :]
    f = fired[:, None]
    pos = weights >= 0                                       # [C, M]

    # Type I where (target & supportive) or (negative & opposing);
    # Type II where the clause's weight sign conflicts with the class.
    t1_cm = jnp.logical_and(sel, jnp.where(is_tgt[None, :], pos, ~pos))
    t2_cm = jnp.logical_and(sel, jnp.where(is_tgt[None, :], ~pos, pos))
    type1 = t1_cm.any(axis=1)
    type2 = t2_cm.any(axis=1)

    # Type I (recognize)
    r_hi = _bernoulli_u8(k_hi, (s - 1.0) / s, ta_state.shape)
    r_lo = _bernoulli_u8(k_lo, 1.0 / s, ta_state.shape)
    inc_t1 = jnp.logical_and(jnp.logical_and(f, lit1), r_hi)
    dec_t1 = jnp.logical_and(
        jnp.logical_or(~f, jnp.logical_and(f, ~lit1)), r_lo)
    d1 = (inc_t1.astype(jnp.int8) - dec_t1.astype(jnp.int8)) \
        * type1[:, None].astype(jnp.int8)
    # Type II (reject) on firing clauses
    excl = ta_state <= cfg.n_states
    inc_t2 = jnp.logical_and(jnp.logical_and(f, ~lit1), excl)
    d2 = inc_t2.astype(jnp.int8) * jnp.logical_and(
        type2, fired)[:, None].astype(jnp.int8)
    d_state = d1 + d2

    # weight deltas on firing clauses: +1 toward the target column,
    # -1 on the selected negative column
    dw = jnp.where(is_tgt[None, :], 1, -1).astype(jnp.int8) \
        * jnp.logical_and(sel, f).astype(jnp.int8)
    return d_state, dw


@partial(jax.jit, static_argnames=("cfg",))
def train_step_batch(ta_state, weights, key, x, y, cfg: CoalescedConfig):
    lits_b = literals(x)
    cls = clause_outputs(ta_state, lits_b, cfg, training=True)
    sums = cls.astype(jnp.int32) @ weights
    keys = jax.random.split(key, x.shape[0])
    d_state, d_w = jax.vmap(
        lambda k, l, c, s, yy: _example_update(
            k, ta_state, weights, l, c, s, yy, cfg)
    )(keys, lits_b, cls, sums, y)
    new_state = _clip_state(
        ta_state.astype(jnp.int32) + d_state.astype(jnp.int32).sum(0),
        dataclasses.replace(cfg, state_dtype=cfg.state_dtype))
    new_w = jnp.clip(weights + d_w.astype(jnp.int32).sum(0),
                     -cfg.max_weight, cfg.max_weight)
    return new_state, new_w


def fit(ta_state, weights, key, x, y, cfg: CoalescedConfig, *,
        epochs=10, batch_size=256):
    n = x.shape[0]
    for _ in range(epochs):
        key, kp, ks = jax.random.split(key, 3)
        perm = jax.random.permutation(kp, n)
        xs, ys = x[perm], y[perm]
        for i in range(0, n - batch_size + 1, batch_size):
            ks, kb = jax.random.split(ks)
            ta_state, weights = train_step_batch(
                ta_state, weights, kb, xs[i:i + batch_size],
                ys[i:i + batch_size], cfg)
    return ta_state, weights
