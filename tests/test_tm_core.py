"""Unit tests for the digital TM core (tm.py / tm_train.py / booleanize)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import tm, tm_train
from repro.core.booleanize import binarize, fit_quantile, fit_uniform
from repro.core.tm import TMConfig
from repro.data.tm_datasets import noisy_xor


CFG = TMConfig(n_classes=2, clauses_per_class=4, n_features=6, n_states=50)


def test_literals_layout():
    x = jnp.array([[1, 0, 1]], dtype=jnp.uint8)
    lits = tm.literals(x)
    np.testing.assert_array_equal(np.asarray(lits), [[1, 0, 1, 0, 1, 0]])


def test_init_state_on_boundary():
    st = tm.init_ta_state(jax.random.PRNGKey(0), CFG)
    assert st.shape == (CFG.n_clauses, CFG.n_literals)
    assert int(st.min()) >= CFG.n_states
    assert int(st.max()) <= CFG.n_states + 1


def test_polarity_interleaved():
    pol = np.asarray(tm.polarity(CFG))
    assert pol.shape == (CFG.n_clauses,)
    np.testing.assert_array_equal(pol[: CFG.clauses_per_class], [1, -1, 1, -1])


def test_clause_outputs_manual():
    # 1 clause, 2 features (4 literals). Include literal 0 (= feature 0).
    cfg = TMConfig(n_classes=1, clauses_per_class=2, n_features=2)
    state = jnp.full((2, 4), cfg.n_states, dtype=jnp.int16)
    state = state.at[0, 0].set(cfg.n_states + 1)   # clause 0 includes f0
    lits = tm.literals(jnp.array([[1, 0], [0, 0]], dtype=jnp.uint8))
    out = tm.clause_outputs(state, lits, cfg, training=True)
    # clause 0 fires iff f0 == 1; clause 1 is empty -> 1 in training.
    np.testing.assert_array_equal(np.asarray(out), [[1, 1], [0, 1]])
    out_inf = tm.clause_outputs(state, lits, cfg, training=False)
    np.testing.assert_array_equal(np.asarray(out_inf), [[1, 0], [0, 0]])


def test_class_sums_polarity():
    cfg = TMConfig(n_classes=2, clauses_per_class=2, n_features=2)
    clauses = jnp.array([[1, 1, 1, 0]], dtype=jnp.uint8)
    sums = tm.class_sums(clauses, cfg)
    np.testing.assert_array_equal(np.asarray(sums), [[0, 1]])


def test_training_learns_xor():
    key = jax.random.PRNGKey(0)
    xtr, ytr, xte, yte = noisy_xor(key, n_train=3000, n_test=1000)
    cfg = TMConfig(n_classes=2, clauses_per_class=12, n_features=12,
                   n_states=100, threshold=15, specificity=3.9)
    ta = tm.init_ta_state(jax.random.PRNGKey(1), cfg)
    ta = tm_train.fit(ta, jax.random.PRNGKey(2), xtr, ytr, cfg,
                      epochs=60, batch_size=1500)
    acc = float(tm.accuracy(ta, xte, yte, cfg))
    assert acc >= 0.97, acc   # paper reports 99.2 on this benchmark


def test_batch_parallel_training_learns_xor():
    key = jax.random.PRNGKey(0)
    xtr, ytr, xte, yte = noisy_xor(key, n_train=3000, n_test=1000)
    cfg = TMConfig(n_classes=2, clauses_per_class=12, n_features=12,
                   n_states=100, threshold=15, specificity=3.9)
    ta = tm.init_ta_state(jax.random.PRNGKey(1), cfg)
    ta = tm_train.fit(ta, jax.random.PRNGKey(2), xtr, ytr, cfg,
                      epochs=60, batch_size=64, parallel=True)
    acc = float(tm.accuracy(ta, xte, yte, cfg))
    assert acc >= 0.95, acc


def test_state_bounds_preserved():
    key = jax.random.PRNGKey(0)
    xtr, ytr, *_ = noisy_xor(key, n_train=512, n_test=10)
    ta = tm.init_ta_state(jax.random.PRNGKey(1), CFG)
    x = xtr[:, : CFG.n_features]
    ta = tm_train.train_step(ta, jax.random.PRNGKey(3), x, ytr, CFG)
    assert int(ta.min()) >= 1 and int(ta.max()) <= 2 * CFG.n_states
    ta2 = tm_train.train_step_batch(ta, jax.random.PRNGKey(4), x, ytr, CFG)
    assert int(ta2.min()) >= 1 and int(ta2.max()) <= 2 * CFG.n_states


def test_booleanizer_thermometer_monotone():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(200, 5)).astype(np.float32)
    for fit in (fit_quantile, fit_uniform):
        b = fit(x, bits=4)
        bits = np.asarray(b.transform(jnp.asarray(x)))
        assert bits.shape == (200, 20)
        folded = bits.reshape(200, 5, 4).astype(np.int32)
        # thermometer: once a bit drops to 0, all later bits are 0
        assert (np.diff(folded, axis=-1) <= 0).all()


def test_binarize():
    x = jnp.array([[0.2, 0.7]])
    np.testing.assert_array_equal(np.asarray(binarize(x, 0.5)), [[0, 1]])


def test_config_validation():
    with pytest.raises(ValueError):
        TMConfig(n_classes=2, clauses_per_class=3, n_features=4)
