"""Serving-engine benchmark: dynamic batching x replica-pool sweep.

Measures the simulator's serving throughput/latency across
(max-batch, replica-count) configurations and against the seed's
per-request serial path (one kernel dispatch per request — what
``launch/serve.py`` did before the engine existed).  Writes
``BENCH_serve.json`` next to the repo root.

ISSUE 3 additions: the default datapath is the **packed** uint32
literal wire (packed once per request at submit; see
``serve/batching.py``) with **measured** kernel tiles and bucket
ladders from the registry tuning table (``kernels/autotune.py``).  The
report carries an explicit before/after pair at the headline cell
(R=4, batch 64): ``before_unpacked_static`` re-measures the PR-2
configuration (dense uint8 wire, static buckets, default tiles) on the
same host, next to the packed+tuned ``sweep`` rows.  Each timed
configuration is run ``--repeats`` times and the best run is reported —
wall-clock on a shared CPU container is noisy and every positive
excursion is interference, not the engine.

Interpret-mode Pallas on CPU means absolute numbers are simulator
figures, not hardware ones; the hardware figures of merit are reported
separately by ``repro.serve.metrics.hardware_figures``.  The quantities
that transfer are the *relative* win of batching/tuning and the
bytes-moved-per-dispatch column, which is exactly the HBM/interconnect
traffic a real accelerator would carry.

ISSUE 4 additions: **async** rows (``AsyncServeEngine`` double-buffers
dispatches so host packing overlaps device compute; the headline pair is
sync vs async at R=4 batch=64 on the same host) and **sharded** rows
(the pool's ``[R, C, L]`` stack split over a ``replica`` device mesh;
needs >1 device — pass ``--host-devices 8`` to force CPU host devices
before jax initializes).  Sharded rows ride the GSPMD jnp backend by
capability (``CAP_SHARDED``); on forced CPU devices they measure
*mechanics*, not a speedup — the fake devices share one physical socket.

ISSUE 6 additions: a **capacity head-to-head** at equal device budget —
the same 8-class workload served by a replicated per-class analog pool
(R=4 routed chips) vs ONE coalesced shared clause pool with half the
clause rows and the weighted digital tail (``run_capacity_pair``, runs
interleaved like the sync/async pair).  Rows carry ``host_cpus`` and
their total TA-cell budgets; the smoke adds a coalesced leg that must
select a ``coalesced*`` backend with zero fallbacks.

ISSUE 9 additions: the default datapath is now **plane-packed** — the
programmed conductance stack rides as a uint32 LRS/HRS index bitplane
(+ a per-cell deviation plane off-nominal) and serving selects the
``*-packed2`` backends.  The report adds a second before/after pair at
the headline cell: ``planes_before_r4_b64`` (packed wire, dense
resident planes, backend ``analog-pallas-packed``) vs the plane-packed
default, with the ``resident_bytes_per_dispatch`` drop — the resident
HBM traffic a real accelerator would stream per dispatch.

  PYTHONPATH=src python -m benchmarks.serve_bench [--requests 192]
  PYTHONPATH=src python -m benchmarks.serve_bench --host-devices 8
  PYTHONPATH=src python -m benchmarks.serve_bench --smoke   # CI, no JSON
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

from repro.launch.hostdev import force_host_devices

force_host_devices(sys.argv[1:])   # must precede the first jax import

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.core.tm import TMConfig
from repro.core.variations import VariationConfig
from repro.launch.mesh import make_replica_mesh
from repro.serve import (AsyncServeEngine, BatcherConfig, EngineConfig,
                         ServeEngine)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def make_model(key):
    """Small trained-free TM (sparse random includes) — the bench measures
    serving mechanics, not accuracy."""
    cfg = TMConfig(n_classes=4, clauses_per_class=8, n_features=64,
                   n_states=100)
    inc = jax.random.bernoulli(key, 0.1, (cfg.n_clauses, cfg.n_literals))
    ta = jnp.where(inc, cfg.n_states + 1, cfg.n_states).astype(
        cfg.state_dtype)
    return cfg, ta


def make_engine(cfg, ta, *, max_batch, n_replicas, routing="round_robin",
                backend=None, packed=True, pack_planes=True,
                static_buckets=False, engine_cls=ServeEngine, mesh=None):
    # CSA offset off so serving stays on the fused Pallas kernel path
    # (capability selection would reject the pallas backends otherwise;
    # see repro.api.select_backend).
    if static_buckets:
        from repro.serve.batching import STATIC_BUCKETS
        sizes = tuple(b for b in STATIC_BUCKETS if b < max_batch)
        batcher = BatcherConfig(max_batch=max_batch,
                                bucket_sizes=sizes + (max_batch,))
    else:
        batcher = BatcherConfig.for_max_batch(max_batch)
    return engine_cls.from_ta_state(
        ta, cfg, n_replicas=n_replicas, key=jax.random.PRNGKey(3),
        vcfg=VariationConfig(csa_offset=False),
        ecfg=EngineConfig(batcher=batcher, routing=routing,
                          backend=backend, packed=packed,
                          pack_planes=pack_planes),
        mesh=mesh)


def run_batched(cfg, ta, xs, *, max_batch, n_replicas, routing,
                backend=None, packed=True, static_buckets=False,
                repeats=3, engine_cls=ServeEngine, mesh=None):
    """Submit everything, then drain: batches cut at ``max_batch``.

    Best of ``repeats`` timed runs (one warmed engine) — see module
    docstring for why best-of is the right de-noising on a shared host.
    """
    engine = make_engine(cfg, ta, max_batch=max_batch,
                         n_replicas=n_replicas, routing=routing,
                         backend=backend, packed=packed,
                         static_buckets=static_buckets,
                         engine_cls=engine_cls, mesh=mesh)
    engine.submit_many([xs[0]] * max_batch)   # warm the kernel cache
    engine.drain()
    best_wall, best_summary = float("inf"), None
    for _ in range(max(1, repeats)):
        engine.metrics = type(engine.metrics)()
        t0 = time.monotonic()
        engine.submit_many(list(xs))
        engine.drain()
        wall = time.monotonic() - t0
        if wall < best_wall:
            best_wall, best_summary = wall, engine.summary()
    out = best_summary
    out["wall_s"] = best_wall
    out["wall_throughput_rps"] = len(xs) / best_wall
    out["max_batch"] = max_batch
    out["async"] = engine_cls is AsyncServeEngine
    return out


def run_async_pair(cfg, ta, xs, *, max_batch, n_replicas, repeats=3,
                   backend=None, packed=True, mesh=None):
    """Sync vs async on the SAME workload, runs interleaved.

    Wall-clock on a shared host drifts over minutes; alternating the two
    engines run-for-run makes the sync/async ratio robust to that drift
    in a way two back-to-back sweeps are not.  Best-of per engine."""
    engines = {}
    for is_async in (False, True):
        eng = make_engine(cfg, ta, max_batch=max_batch,
                          n_replicas=n_replicas, routing="round_robin",
                          backend=backend, packed=packed, mesh=mesh,
                          engine_cls=(AsyncServeEngine if is_async
                                      else ServeEngine))
        eng.submit_many([xs[0]] * max_batch)      # warm the kernel cache
        eng.drain()
        engines[is_async] = eng
    best = {False: (float("inf"), None), True: (float("inf"), None)}
    for _ in range(max(1, repeats)):
        for is_async in (False, True):
            eng = engines[is_async]
            eng.metrics = type(eng.metrics)()
            t0 = time.monotonic()
            eng.submit_many(list(xs))
            eng.drain()
            wall = time.monotonic() - t0
            if wall < best[is_async][0]:
                best[is_async] = (wall, eng.summary())
    rows = {}
    for is_async in (False, True):
        wall, summary = best[is_async]
        summary["wall_s"] = wall
        summary["wall_throughput_rps"] = len(xs) / wall
        summary["max_batch"] = max_batch
        summary["async"] = is_async
        rows[is_async] = summary
    return rows[False], rows[True]


def run_planes_pair(cfg, ta, xs, *, max_batch, n_replicas, repeats=3,
                    packed=True):
    """Dense resident planes vs plane-packed at the headline cell,
    runs interleaved (ISSUE 9).

    Both engines use the packed literal wire and measured tuning; only
    the resident format differs — ``pack_planes=False`` serves on
    ``analog-pallas-packed`` (two dense f32 conductance/leak planes per
    dispatch), the default serves on ``analog-pallas-packed2`` (uint32
    index bitplane + deviation plane).  The transferable number is the
    ``resident_bytes_per_dispatch`` drop."""
    engines = {}
    for planes in (False, True):
        eng = make_engine(cfg, ta, max_batch=max_batch,
                          n_replicas=n_replicas, routing="round_robin",
                          packed=packed, pack_planes=planes)
        eng.submit_many([xs[0]] * max_batch)      # warm the kernel cache
        eng.drain()
        engines[planes] = eng
    best = {False: (float("inf"), None), True: (float("inf"), None)}
    for _ in range(max(1, repeats)):
        for planes in (False, True):
            eng = engines[planes]
            eng.metrics = type(eng.metrics)()
            t0 = time.monotonic()
            eng.submit_many(list(xs))
            eng.drain()
            wall = time.monotonic() - t0
            if wall < best[planes][0]:
                best[planes] = (wall, eng.summary())
    rows = {}
    for planes in (False, True):
        wall, summary = best[planes]
        summary["wall_s"] = wall
        summary["wall_throughput_rps"] = len(xs) / wall
        summary["max_batch"] = max_batch
        rows[planes] = summary
    return rows[False], rows[True]


def make_capacity_models(key):
    """The equal-device-budget head-to-head pair: one 8-class workload,
    two architectures.

    * **analog**: the per-class TM (8 classes x 8 clauses = 64 clause
      rows) replicated across R routed chips — capacity scales by
      adding crossbars.
    * **coalesced**: ONE shared pool with HALF the clause rows (the
      coalesced capacity lever: clauses are shared between classes, so
      the same accuracy needs ~2x fewer TA cells — paper §V / the CoTM
      result) plus the weighted digital tail, on a single chip.

    Both serve the same requests on the same host devices; weights are
    random (the bench measures serving mechanics, not accuracy)."""
    from repro.core.coalesced import CoalescedConfig
    k1, k2, k3 = jax.random.split(key, 3)
    acfg = TMConfig(n_classes=8, clauses_per_class=8, n_features=64,
                    n_states=100)
    inc = jax.random.bernoulli(k1, 0.1, (acfg.n_clauses, acfg.n_literals))
    ta = jnp.where(inc, acfg.n_states + 1, acfg.n_states).astype(
        acfg.state_dtype)
    ccfg = CoalescedConfig(n_classes=8, n_clauses=acfg.n_clauses // 2,
                           n_features=64, n_states=100)
    cinc = jax.random.bernoulli(k2, 0.1, (ccfg.n_clauses, ccfg.n_literals))
    cta = jnp.where(cinc, ccfg.n_states + 1, ccfg.n_states).astype(
        ccfg.state_dtype)
    w = jax.random.randint(k3, (ccfg.n_clauses, ccfg.n_classes),
                           -ccfg.max_weight, ccfg.max_weight + 1, jnp.int32)
    return acfg, ta, ccfg, cta, w


def run_capacity_pair(xs, *, max_batch, n_replicas=4, repeats=3,
                      packed=True):
    """Replicated analog vs coalesced shared pool, runs interleaved.

    Same de-noising argument as :func:`run_async_pair`: alternating the
    two engines run-for-run keeps the ratio robust to host drift.  Each
    row carries ``host_cpus`` and its total TA-cell budget so the
    energy/capacity story is auditable next to the throughput."""
    acfg, ta, ccfg, cta, w = make_capacity_models(jax.random.PRNGKey(7))
    ecfg = EngineConfig(batcher=BatcherConfig.for_max_batch(max_batch),
                       routing="round_robin", packed=packed)
    engines = {
        "analog": ServeEngine.from_ta_state(
            ta, acfg, n_replicas=n_replicas, key=jax.random.PRNGKey(3),
            vcfg=VariationConfig(csa_offset=False), ecfg=ecfg),
        "coalesced": ServeEngine.from_coalesced(
            cta, w, ccfg, key=jax.random.PRNGKey(3), ecfg=ecfg),
    }
    for eng in engines.values():
        eng.submit_many([xs[0]] * max_batch)   # warm the kernel cache
        eng.drain()
    best = {name: (float("inf"), None) for name in engines}
    for _ in range(max(1, repeats)):
        for name, eng in engines.items():      # interleaved
            eng.metrics = type(eng.metrics)()
            t0 = time.monotonic()
            eng.submit_many(list(xs))
            eng.drain()
            wall = time.monotonic() - t0
            if wall < best[name][0]:
                best[name] = (wall, eng.summary())
    rows = {}
    for name, (wall, summary) in best.items():
        summary["wall_s"] = wall
        summary["wall_throughput_rps"] = len(xs) / wall
        summary["max_batch"] = max_batch
        summary["host_cpus"] = os.cpu_count()
        rows[name] = summary
    rows["analog"]["n_ta_total"] = int(acfg.n_ta) * n_replicas
    rows["coalesced"]["n_ta_total"] = int(ccfg.n_ta)
    return rows["analog"], rows["coalesced"]


def run_serial(cfg, ta, xs, *, n_replicas=1, backend=None, packed=True,
               repeats=3):
    """The seed's per-request path: one dispatch per request."""
    engine = make_engine(cfg, ta, max_batch=8, n_replicas=n_replicas,
                         backend=backend, packed=packed)
    engine.submit(xs[0])
    engine.drain()                             # warm the bucket-8 kernel
    best_wall, best_summary = float("inf"), None
    for _ in range(max(1, repeats)):
        engine.metrics = type(engine.metrics)()
        t0 = time.monotonic()
        for x in xs:
            engine.submit(x)
            engine.drain()                     # force: batch of 1, now
        wall = time.monotonic() - t0
        if wall < best_wall:
            best_wall, best_summary = wall, engine.summary()
    out = best_summary
    out["wall_s"] = best_wall
    out["wall_throughput_rps"] = len(xs) / best_wall
    out["max_batch"] = 1
    return out


def run_before_unpacked_static(cfg, ta, xs, *, repeats=3):
    """The PR-2 configuration on this host: dense uint8 wire, static
    bucket ladder, default (untuned) kernel tiles — the "before" half of
    the headline before/after pair."""
    saved = api.tuning_snapshot()
    api.clear_tuning()
    try:
        return run_batched(cfg, ta, xs, max_batch=64, n_replicas=4,
                           routing="round_robin", packed=False,
                           static_buckets=True, repeats=repeats)
    finally:
        api.restore_tuning(saved)


def run_degraded(cfg, ta, xs, *, max_batch, n_replicas=4, packed=True,
                 repeats=3):
    """ISSUE 8 leg: ensemble throughput with one replica injured and
    quarantined, next to the same engine's healthy figure.

    Builds an R-replica ensemble engine with health probing enabled
    (d2d-only noise so probe scores are deterministic), times a healthy
    pass, injects stuck-at faults into replica 1, probes (which
    quarantines the chip), times a degraded pass over the healthy
    majority, then auto-repairs via ``RepairPolicy`` and re-probes.  The
    interesting number is ``degraded_vs_healthy``: the ensemble keeps
    serving while a chip is down, paying only the lost replica's share
    of vote diversity — dispatch shape (and hence throughput) is
    unchanged because the vote mask is a traced argument."""
    from repro.core.variations import FaultConfig
    from repro.serve import HealthConfig, RepairConfig, RepairPolicy
    ecfg = EngineConfig(batcher=BatcherConfig.for_max_batch(max_batch),
                        routing="ensemble", packed=packed,
                        health=HealthConfig(n_probes=64, seed=5))
    engine = ServeEngine.from_ta_state(
        ta, cfg, n_replicas=n_replicas, key=jax.random.PRNGKey(3),
        vcfg=VariationConfig(c2c=False, csa_offset=False), ecfg=ecfg)
    engine.submit_many([xs[0]] * max_batch)    # warm the kernel cache
    engine.drain()

    def timed_pass():
        best = float("inf")
        for _ in range(max(1, repeats)):
            engine.metrics = type(engine.metrics)()
            t0 = time.monotonic()
            engine.submit_many(list(xs))
            engine.drain()
            best = min(best, time.monotonic() - t0)
        return len(xs) / best

    healthy_rps = timed_pass()
    baseline_health = engine.probe()
    engine.inject_faults(
        jax.random.PRNGKey(99),
        FaultConfig(stuck_lrs_rate=0.15, stuck_hrs_rate=0.15),
        replicas=[1])
    injured_health = engine.probe()            # quarantines replica 1
    quarantined = sorted(engine.quarantined)
    degraded_rps = timed_pass()                # healthy-majority serving
    tick = RepairPolicy(engine, RepairConfig()).check()
    row = engine.summary()
    row.update({
        "max_batch": max_batch,
        "healthy_rps": healthy_rps,
        "degraded_rps": degraded_rps,
        "degraded_vs_healthy": degraded_rps / healthy_rps,
        "baseline_health": baseline_health,
        "injured_health": injured_health,
        "quarantined_during_degraded": quarantined,
        "repairs": tick["repairs"],
        "post_repair_health": tick["health"],
        "recovered": not engine.quarantined,
    })
    return row


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=192,
                    help="requests per batched configuration")
    ap.add_argument("--serial-requests", type=int, default=48,
                    help="requests for the serial baseline (slow path)")
    ap.add_argument("--backend", default=None,
                    choices=("analog-pallas-packed2",
                             "analog-pallas-packed", "analog-pallas",
                             "analog-jnp"),
                    help="forward-backend preference (repro.api name)")
    ap.add_argument("--packed", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="uint32 literal wire format (default on)")
    ap.add_argument("--repeats", type=int, default=3,
                    help="timed runs per configuration (best reported)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: one tiny sweep cell; the committed "
                         "baseline JSON is never touched")
    ap.add_argument("--smoke-out", default=None,
                    help="write the smoke report JSON here (CI uploads "
                         "it as a workflow artifact); default: no write")
    ap.add_argument("--host-devices", type=int, default=None,
                    help="force N CPU host devices before jax init so "
                         "the sharded rows run (XLA_FLAGS=--xla_force_"
                         "host_platform_device_count)")
    ap.add_argument("--out", default=os.path.join(REPO, "BENCH_serve.json"))
    args = ap.parse_args(argv)
    if args.smoke:
        # Exercise the serve hot path (batched + ensemble dispatch through
        # the capability-selected backend) without the full sweep and
        # WITHOUT touching the committed BENCH_serve.json baseline.
        args.requests = min(args.requests, 64)
        args.serial_requests = min(args.serial_requests, 8)
        args.repeats = 1

    cfg, ta = make_model(jax.random.PRNGKey(0))
    xs = np.asarray(jax.random.bernoulli(
        jax.random.PRNGKey(1), 0.4,
        (args.requests, cfg.n_features))).astype(np.uint8)

    print("[serve_bench] serial baseline (per-request dispatch)...")
    serial = run_serial(cfg, ta, xs[:args.serial_requests],
                        backend=args.backend, packed=args.packed,
                        repeats=args.repeats)
    print(f"[serve_bench]   serial: "
          f"{serial['wall_throughput_rps']:.1f} req/s")

    sweep = []
    grid = (((4, 64),) if args.smoke
            else tuple((r, b) for r in (1, 2, 4) for b in (8, 32, 64)))
    for n_replicas, max_batch in grid:
        row = run_batched(cfg, ta, xs, max_batch=max_batch,
                          n_replicas=n_replicas,
                          routing="round_robin", backend=args.backend,
                          packed=args.packed, repeats=args.repeats)
        row["speedup_vs_serial"] = (row["wall_throughput_rps"]
                                    / serial["wall_throughput_rps"])
        sweep.append(row)
        print(f"[serve_bench]   R={n_replicas} batch={max_batch}: "
              f"{row['wall_throughput_rps']:.1f} req/s "
              f"({row['speedup_vs_serial']:.1f}x serial), "
              f"p99 {row['p99_ms']:.1f} ms [{row['backend']}, "
              f"{row['bytes_per_dispatch']:.0f} B/dispatch, "
              f"buckets {row['bucket_sizes']}]")
    ens = run_batched(cfg, ta, xs, max_batch=64, n_replicas=4,
                      routing="ensemble", backend=args.backend,
                      packed=args.packed, repeats=args.repeats)
    ens["speedup_vs_serial"] = (ens["wall_throughput_rps"]
                                / serial["wall_throughput_rps"])
    print(f"[serve_bench]   ensemble R=4 batch=64: "
          f"{ens['wall_throughput_rps']:.1f} req/s")

    # Async overlap at the headline cell: identical config to the sync
    # R=4 batch=64 sweep row, AsyncServeEngine dispatch schedule; the
    # two engines are timed interleaved so host drift can't fake a win.
    sync_row, async_row = run_async_pair(
        cfg, ta, xs, max_batch=64, n_replicas=4, backend=args.backend,
        packed=args.packed, repeats=args.repeats)
    for row in (sync_row, async_row):
        row["speedup_vs_serial"] = (row["wall_throughput_rps"]
                                    / serial["wall_throughput_rps"])
    async_speedup = (async_row["wall_throughput_rps"]
                     / sync_row["wall_throughput_rps"])
    print(f"[serve_bench]   async R=4 batch=64: "
          f"{async_row['wall_throughput_rps']:.1f} req/s = "
          f"{async_speedup:.2f}x sync "
          f"({sync_row['wall_throughput_rps']:.1f} req/s paired), "
          f"overlap {100 * async_row['overlap_fraction']:.0f}%")

    # Plane-packed resident format at the headline cell (ISSUE 9):
    # dense f32 conductance planes vs the uint32 index bitplane, runs
    # interleaved; the resident-bytes column is exact, the wall-clock
    # is interpret-mode color.
    planes_before, planes_after = run_planes_pair(
        cfg, ta, xs, max_batch=64, n_replicas=4, packed=args.packed,
        repeats=args.repeats)
    resident_ratio = (
        planes_after["resident_bytes_per_dispatch"]
        / planes_before["resident_bytes_per_dispatch"]
        if planes_before["resident_bytes_per_dispatch"] else None)
    print(f"[serve_bench]   planes R=4 batch=64: "
          f"{planes_after['backend']} resident "
          f"{planes_before['resident_bytes_per_dispatch']:.0f} -> "
          f"{planes_after['resident_bytes_per_dispatch']:.0f} B/dispatch "
          f"({resident_ratio:.4f}x), "
          f"{planes_after['wall_throughput_rps']:.1f} vs "
          f"{planes_before['wall_throughput_rps']:.1f} req/s paired")

    # Capacity head-to-head (ISSUE 6): replicated analog vs one
    # coalesced shared pool at equal device budget, runs interleaved —
    # the same 8-class workload served by R routed per-class chips vs a
    # single half-size shared clause pool with the weighted tail.
    cap_analog, cap_coalesced = run_capacity_pair(
        xs, max_batch=64, n_replicas=4, packed=args.packed,
        repeats=args.repeats)
    cap_ratio = (cap_coalesced["wall_throughput_rps"]
                 / cap_analog["wall_throughput_rps"])
    print(f"[serve_bench]   capacity head-to-head batch=64: coalesced "
          f"{cap_coalesced['wall_throughput_rps']:.1f} req/s on "
          f"{cap_coalesced['backend']} "
          f"({cap_coalesced['n_ta_total']} TA cells) vs analog R=4 "
          f"{cap_analog['wall_throughput_rps']:.1f} req/s on "
          f"{cap_analog['backend']} ({cap_analog['n_ta_total']} TA "
          f"cells) = {cap_ratio:.2f}x")

    # Sharded rows: the pool split over a replica device mesh.  On
    # forced CPU host devices this measures mechanics (the jnp GSPMD
    # backend on fake devices sharing one socket), not a speedup.
    sharded = []
    n_dev = jax.device_count()
    for n_replicas, use_async, routing in (
            (4, False, "round_robin"), (4, True, "round_robin"),
            (8, True, "round_robin"), (8, False, "ensemble")):
        if n_replicas > n_dev or args.smoke:
            continue
        mesh = make_replica_mesh(n_replicas, 1)
        row = run_batched(cfg, ta, xs, max_batch=64,
                          n_replicas=n_replicas, routing=routing,
                          backend=args.backend, packed=args.packed,
                          repeats=args.repeats, mesh=mesh,
                          engine_cls=(AsyncServeEngine if use_async
                                      else ServeEngine))
        row["speedup_vs_serial"] = (row["wall_throughput_rps"]
                                    / serial["wall_throughput_rps"])
        sharded.append(row)
        print(f"[serve_bench]   sharded R={n_replicas} batch=64 "
              f"({routing}{', async' if use_async else ''}): "
              f"{row['wall_throughput_rps']:.1f} req/s on "
              f"{row['backend']}, mesh {row['mesh']}, overlap "
              f"{100 * row['overlap_fraction']:.0f}%")
    if not sharded and not args.smoke:
        print(f"[serve_bench]   sharded rows skipped: {n_dev} device(s) "
              "visible (pass --host-devices 8)")

    if args.smoke:
        # Degraded-serving leg (ISSUE 8): one replica injured, probed,
        # quarantined, served around, repaired — smoke-only so the
        # committed BENCH_serve.json schema is untouched.
        deg = run_degraded(cfg, ta, xs, max_batch=64, n_replicas=4,
                           packed=args.packed, repeats=args.repeats)
        print(f"[serve_bench]   degraded R=4 batch=64: "
              f"{deg['degraded_rps']:.1f} req/s with "
              f"{deg['quarantined_during_degraded']} quarantined = "
              f"{deg['degraded_vs_healthy']:.2f}x healthy "
              f"({deg['healthy_rps']:.1f} req/s), "
              f"recovered={deg['recovered']}")
        row = sweep[0]
        coalesced_ok = (
            cap_coalesced["backend"].startswith("coalesced")
            and cap_coalesced["forward_fallbacks"] == [])
        degraded_ok = (deg["quarantined_during_degraded"] == [1]
                       and deg["recovered"]
                       and deg["forward_fallbacks"] == [])
        planes_ok = (
            planes_after["backend"] == "analog-pallas-packed2"
            and planes_after["forward_fallbacks"] == []
            and planes_after["resident_bytes_per_dispatch"]
            < planes_before["resident_bytes_per_dispatch"])
        ok = (row["speedup_vs_serial"] >= 1.5
              and row["forward_fallbacks"] == []
              and async_row["forward_fallbacks"] == []
              and coalesced_ok
              and degraded_ok
              and planes_ok)
        print(f"[serve_bench] SMOKE {'PASS' if ok else 'FAIL'}: "
              f"{row['speedup_vs_serial']:.1f}x serial on "
              f"{row['backend']}, async {async_speedup:.2f}x sync, "
              f"coalesced leg on {cap_coalesced['backend']} "
              f"({'clean' if coalesced_ok else 'FALLBACK'}), "
              f"degraded leg {'healed' if degraded_ok else 'FAILED'}, "
              f"planes leg {resident_ratio:.4f}x resident "
              f"({'clean' if planes_ok else 'FAILED'}) "
              f"(committed baseline untouched)")
        if args.smoke_out:
            with open(args.smoke_out, "w") as f:
                json.dump({"smoke": True, "devices": n_dev,
                           "serial_baseline": serial, "sweep": sweep,
                           "ensemble": ens, "async_r4_b64": async_row,
                           "async_speedup_vs_sync": async_speedup,
                           "capacity_analog_r4_b64": cap_analog,
                           "capacity_coalesced_b64": cap_coalesced,
                           "capacity_coalesced_vs_analog": cap_ratio,
                           "degraded_ensemble_r4_b64": deg,
                           "planes_before_r4_b64": planes_before,
                           "planes_after_r4_b64": planes_after,
                           "resident_bytes_ratio_planes": resident_ratio},
                          f, indent=2, default=str)
            print(f"[serve_bench] wrote smoke report to {args.smoke_out}")
        if not ok:
            raise SystemExit(1)
        return None

    print("[serve_bench] before: PR-2 config (unpacked, static buckets, "
          "default tiles) on this host...")
    before = run_before_unpacked_static(cfg, ta, xs, repeats=args.repeats)
    print(f"[serve_bench]   before R=4 batch=64: "
          f"{before['wall_throughput_rps']:.1f} req/s "
          f"[{before['backend']}, "
          f"{before['bytes_per_dispatch']:.0f} B/dispatch]")

    # The previously committed headline (possibly from another host /
    # another PR): captured before this run overwrites the file, so the
    # regenerated JSON always carries its own point of comparison.
    prev_rps = None
    if os.path.exists(args.out):
        try:
            with open(args.out) as f:
                prev = json.load(f)
            prev_rps = prev.get("headline_r4_b64_rps")
            if prev_rps is None:        # PR-2 schema: find the sweep row
                prev_rps = next(
                    (r["wall_throughput_rps"] for r in prev.get("sweep", [])
                     if r.get("max_batch") == 64
                     and r.get("n_replicas") == 4), None)
            prev_rps = float(prev_rps) if prev_rps is not None else None
        except (OSError, json.JSONDecodeError, TypeError, ValueError):
            prev_rps = None

    at64 = [r for r in sweep
            if r["max_batch"] == 64 and r["n_replicas"] == 1]
    speedup64 = at64[0]["speedup_vs_serial"]
    after = sync_row
    headline = (after["wall_throughput_rps"]
                / before["wall_throughput_rps"])
    report = {
        "model": {"n_clauses": cfg.n_clauses,
                  "n_literals": cfg.n_literals,
                  "n_classes": cfg.n_classes},
        "backend": jax.default_backend(),
        "devices": n_dev,
        "host_cpus": os.cpu_count(),
        "requests": args.requests,
        "repeats": args.repeats,
        "serial_baseline": serial,
        "sweep": sweep,
        "ensemble": ens,
        "sync_r4_b64_paired": sync_row,
        "async_r4_b64": async_row,
        "async_speedup_vs_sync_r4_b64": async_speedup,
        "async_overlap_fraction": async_row["overlap_fraction"],
        "sync_overlap_fraction": sync_row["overlap_fraction"],
        "sharded": sharded,
        "capacity_analog_r4_b64": cap_analog,
        "capacity_coalesced_b64": cap_coalesced,
        "capacity_coalesced_vs_analog": cap_ratio,
        "before_unpacked_static": before,
        "speedup_batch64_vs_serial": speedup64,
        "headline_r4_b64_rps": after["wall_throughput_rps"],
        "headline_speedup_vs_before": headline,
        "previous_committed_r4_b64_rps": prev_rps,
        "headline_speedup_vs_previous_committed": (
            after["wall_throughput_rps"] / prev_rps if prev_rps else None),
        # Cross-commit throughput ratios compare different hosts/device
        # configs (e.g. --host-devices 8 adds fake-device overhead the
        # single-device baseline never paid); same-run pairs above are
        # the apples-to-apples numbers.
        "previous_committed_note": (
            "previous baseline may predate --host-devices forcing or come "
            f"from a larger host; this run saw {n_dev} device(s) on "
            f"{os.cpu_count()} CPU core(s)"),
        "bytes_per_dispatch_before": before["bytes_per_dispatch"],
        "bytes_per_dispatch_after": after["bytes_per_dispatch"],
        # ISSUE 9 pair: dense f32 resident planes vs the plane-packed
        # index bitplane at the same cell, runs interleaved.
        "planes_before_r4_b64": planes_before,
        "planes_after_r4_b64": planes_after,
        "resident_bytes_per_dispatch_before": (
            planes_before["resident_bytes_per_dispatch"]),
        "resident_bytes_per_dispatch_after": (
            planes_after["resident_bytes_per_dispatch"]),
        "resident_bytes_ratio_planes": resident_ratio,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2, default=str)
    print(f"[serve_bench] wrote {args.out}")
    print(f"[serve_bench] dynamic batching at 64: "
          f"{speedup64:.1f}x the serial path "
          f"({'PASS' if speedup64 >= 1.5 else 'FAIL'} >= 1.5x)")
    print(f"[serve_bench] headline R=4 batch=64: "
          f"{after['wall_throughput_rps']:.1f} req/s = "
          f"{headline:.2f}x the same-host before-config; operand "
          f"bytes/dispatch {before['bytes_per_dispatch']:.0f} -> "
          f"{after['bytes_per_dispatch']:.0f}")
    print(f"[serve_bench] plane-packed resident R=4 batch=64: "
          f"{report['resident_bytes_per_dispatch_before']:.0f} -> "
          f"{report['resident_bytes_per_dispatch_after']:.0f} B/dispatch "
          f"({'PASS' if resident_ratio and resident_ratio < 1.0 else 'FAIL'}"
          f" < 1.0x)")
    print(f"[serve_bench] async overlap at R=4 batch=64: "
          f"{async_speedup:.2f}x the synchronous packed baseline "
          f"({'PASS' if async_speedup >= 1.0 else 'FAIL'} >= 1.0x), "
          f"overlap {100 * async_row['overlap_fraction']:.0f}% vs "
          f"{100 * sync_row['overlap_fraction']:.0f}% sync")
    if prev_rps:
        ratio = after["wall_throughput_rps"] / prev_rps
        print(f"[serve_bench] vs previously committed baseline "
              f"({prev_rps:.1f} req/s): {ratio:.2f}x "
              f"({'PASS' if ratio >= 1.0 else 'FAIL'} >= 1.0x, "
              f"no regression)")
    return report


if __name__ == "__main__":
    main()
