"""Distributed TM training/serving demo on 8 (forced) CPU devices.

Shards a K-MNIST-scale TM (7.84M TA cells) the way the production mesh
would: batch over 'data', clauses over 'model'; trains batch-parallel
steps and serves fused digital + analog inference, all under pjit.

  PYTHONPATH=src python examples/tm_scaleout.py
"""

import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import time  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import tm, tm_distributed as tmd  # noqa: E402
from repro.core.tm import TMConfig  # noqa: E402
from repro.data.tm_datasets import synthetic_image_dataset  # noqa: E402
from repro.launch.mesh import make_debug_mesh  # noqa: E402


def main():
    # image-scale TM (reduced clause count for the CPU demo)
    cfg = TMConfig(n_classes=10, clauses_per_class=40, n_features=784,
                   n_states=127, threshold=15, specificity=5.0)
    mesh = make_debug_mesh(2, 4)   # data=2 x model=4
    print(f"mesh {dict(mesh.shape)}, TM {cfg.n_ta} TA cells, "
          f"clauses sharded over 'model'")

    xtr, ytr, xte, yte = synthetic_image_dataset(
        jax.random.PRNGKey(0), n_train=2048, n_test=512)
    st_sh, x_sh, y_sh = tmd.tm_shardings(cfg, mesh, 256)
    ta = jax.device_put(tm.init_ta_state(jax.random.PRNGKey(1), cfg),
                        st_sh)
    step = jax.jit(tmd.tm_train_step, static_argnames=("cfg",),
                   in_shardings=(st_sh, None, x_sh, y_sh),
                   out_shardings=st_sh, donate_argnums=(0,))
    infer = jax.jit(tmd.tm_infer_step, static_argnames=("cfg",),
                    in_shardings=(st_sh, x_sh), out_shardings=None)

    key = jax.random.PRNGKey(2)
    n, bs = xtr.shape[0], 256
    t0 = time.time()
    for epoch in range(6):
        key, kp = jax.random.split(key)
        perm = jax.random.permutation(kp, n)
        for i in range(0, n - bs + 1, bs):
            key, kb = jax.random.split(key)
            xb = jax.device_put(xtr[perm[i:i + bs]], x_sh)
            yb = jax.device_put(ytr[perm[i:i + bs]], y_sh)
            ta = step(ta, kb, xb, yb, cfg)
        pred = infer(ta, jax.device_put(xte, x_sh), cfg)
        acc = float((np.asarray(pred) == np.asarray(yte)).mean())
        print(f"epoch {epoch}: test acc {acc:.3f} "
              f"({time.time() - t0:.0f}s)")
    stats = tm.include_stats(jax.device_get(ta), cfg)
    print(f"includes: {stats['include_pct']:.2f}% "
          f"(drives the IMBUE energy advantage)")


if __name__ == "__main__":
    main()
