"""Pallas TPU kernels for digital TM clause evaluation + fused inference.

The crossbar insight, MXU-shaped (DESIGN.md §2): clause evaluation is a
binary matmul ``viol[b, c] = sum_i lit0[b, i] * include[c, i]`` followed by
a threshold (``viol == 0``), and class sums are a second (tiny) matmul
against a signed polarity one-hot.  Fusing threshold + polarity matmul into
the violation matmul keeps clause bits in VMEM — they never touch HBM.

Two kernels:

``clause_eval_kernel``  grid (B/bt, C/ct, L/kt); f32 violation accumulator
                        in VMEM scratch; emits 0/1 clause block on the last
                        K step.
``tm_infer_kernel``     same, plus on the last K step accumulates
                        ``clauses @ pol`` into the [bt, M] output block
                        (revisited across the C grid dimension).

Blocks are MXU-aligned (128 multiples); all accumulation is f32.  Inputs
arrive pre-transposed (``include_t [L, C]``) so the violation matmul is a
plain ``[bt, kt] @ [kt, ct]``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams


def clause_eval_kernel(lit0_ref, inc_t_ref, out_ref, acc_ref):
    """One (b, c, k) grid step of the violation matmul + threshold."""
    k = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(lit0_ref[...], inc_t_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _emit():
        out_ref[...] = (acc_ref[...] == 0.0).astype(out_ref.dtype)


def tm_infer_kernel(lit0_ref, inc_t_ref, pol_ref, out_ref, acc_ref):
    """Fused: violation matmul -> threshold -> polarity matmul."""
    c = pl.program_id(1)
    k = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(lit0_ref[...], inc_t_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(jnp.logical_and(k == nk - 1, c == 0))
    def _init_out():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(k == nk - 1)
    def _emit():
        clauses = (acc_ref[...] == 0.0).astype(jnp.float32)
        out_ref[...] += jnp.dot(clauses, pol_ref[...],
                                preferred_element_type=jnp.float32)


def clause_eval_call(lit0, inc_t, *, bt, ct, kt, interpret):
    """``[B, L] x [L, C] -> [B, C]`` clause outputs (padded shapes)."""
    b, l = lit0.shape
    c = inc_t.shape[1]
    grid = (b // bt, c // ct, l // kt)
    return pl.pallas_call(
        clause_eval_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, kt), lambda i, j, k: (i, k)),
            pl.BlockSpec((kt, ct), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bt, ct), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, c), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bt, ct), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(lit0, inc_t)


def tm_infer_call(lit0, inc_t, pol, *, bt, ct, kt, interpret):
    """``[B, L] x [L, C] x [C, M] -> [B, M]`` fused class sums (padded)."""
    b, l = lit0.shape
    c = inc_t.shape[1]
    m = pol.shape[1]
    grid = (b // bt, c // ct, l // kt)
    return pl.pallas_call(
        tm_infer_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, kt), lambda i, j, k: (i, k)),
            pl.BlockSpec((kt, ct), lambda i, j, k: (k, j)),
            pl.BlockSpec((ct, m), lambda i, j, k: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bt, m), lambda i, j, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, m), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bt, ct), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(lit0, inc_t, pol)
