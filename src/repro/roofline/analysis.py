"""Roofline analysis from compiled HLO.

XLA's ``compiled.cost_analysis()`` counts a while (scan) body ONCE —
verified empirically (tests/test_roofline.py) — and our stacks scan over
layers, so raw numbers undercount by ~n_layers.  This module therefore
walks the *optimized, SPMD-partitioned* HLO text itself:

* **flops**: every ``dot`` (2 x prod(result dims) x prod(lhs contracting
  dims)), recursing into fusion/call/while computations, with while-body
  costs multiplied by the loop trip count parsed from the loop condition
  (jax scans lower to counted loops — the condition compares the
  induction variable against a constant).
* **bytes**: per instruction at fusion granularity (operand + result
  buffer sizes of compute ops) — a post-fusion proxy for HBM traffic.
* **collective_bytes**: operand bytes of all-reduce / all-gather /
  reduce-scatter / all-to-all / collective-permute (x2 algorithmic factor
  for all-reduce), same while scaling.

Because the module is already partitioned, all shapes are per-device:
``compute_s = flops / peak_flops`` directly (no further /chips).

Hardware constants (TPU v5e-class, per the brief): 197 TFLOP/s bf16,
819 GB/s HBM, ~50 GB/s/link ICI (x4 links usable per chip per axis-pair
in a 2D torus; we use 1 link per collective direction — conservative).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def xla_cost_dict(cost):
    """Normalize ``compiled.cost_analysis()`` across jax versions.

    jax <= 0.4.x returns a single-element list of dicts; newer releases
    return the dict directly.  Returns a dict or None.
    """
    if isinstance(cost, (list, tuple)):
        return cost[0] if cost else None
    return cost


def _shape_bytes(dtype: str, dims: str) -> int:
    bpe = _DTYPE_BYTES.get(dtype)
    if bpe is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * bpe


def _all_shape_bytes(text: str) -> int:
    return sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(text))


@dataclasses.dataclass
class _Instr:
    name: str
    op: str
    result: str          # result type text
    args: str            # text inside the op's parentheses
    attrs: str           # text after the closing paren


_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*((?:\([^()]*\)|[a-z][a-z0-9]*\["
    r"[0-9,]*\](?:\{[^}]*\})?))\s+([\w\-]+)\((.*?)\)(.*)$")


def parse_computations(hlo: str) -> Tuple[Dict[str, List[_Instr]],
                                          Dict[str, Dict[str, str]]]:
    """computation name -> instruction list (entry as '@entry'), plus a
    per-computation map of instruction name -> result type text (modern
    HLO references operands by name without inline shapes)."""
    comps: Dict[str, List[_Instr]] = {}
    types: Dict[str, Dict[str, str]] = {}
    cur: Optional[str] = None
    for line in hlo.splitlines():
        header = re.match(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{",
                          line)
        if header:
            cur = "@entry" if header.group(1) else header.group(2)
            comps[cur] = []
            types[cur] = {}
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if m:
            ins = _Instr(name=m.group(1), result=m.group(2), op=m.group(3),
                         args=m.group(4), attrs=m.group(5))
            comps[cur].append(ins)
            types[cur][ins.name] = ins.result
    return comps, types


_NAME_RE = re.compile(r"%([\w\.\-]+)")


def _operand_types(ins: _Instr, comp_types: Dict[str, str]) -> List[str]:
    """Result-type texts of an instruction's operands (resolved by name,
    falling back to inline shapes for older HLO dumps)."""
    out = []
    for tok in ins.args.split(","):
        tok = tok.strip()
        if not tok:
            continue
        inline = _SHAPE_RE.findall(tok.split("%")[0])
        nm = _NAME_RE.search(tok)
        if nm and nm.group(1) in comp_types:
            out.append(comp_types[nm.group(1)])
        elif inline:
            out.append(tok)
    return out


def _trip_count(cond_instrs: List[_Instr]) -> int:
    """Trip count of a counted loop: the largest integer constant compared
    against in the condition computation (jax scans compare the induction
    variable to the length)."""
    best = 1
    for ins in cond_instrs:
        if ins.op == "constant":
            m = re.search(r"constant\((\d+)\)", f"constant({ins.args})")
            if m:
                best = max(best, int(m.group(1)))
        for m in re.finditer(r"constant\((\d+)\)", ins.args + ins.attrs):
            best = max(best, int(m.group(1)))
    return best


def _dot_flops(ins: _Instr, comp_types: Dict[str, str]) -> float:
    res = _SHAPE_RE.findall(ins.result)
    if not res:
        return 0.0
    _, rdims = res[0]
    rprod = 1
    for d in rdims.split(","):
        if d:
            rprod *= int(d)
    ops = _operand_types(ins, comp_types)
    if not ops:
        return 0.0
    lhs = _SHAPE_RE.findall(ops[0])
    if not lhs:
        return 0.0
    lhs_dims = [int(d) for d in lhs[0][1].split(",") if d]
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.attrs)
    cprod = 1
    if m and m.group(1):
        for ix in m.group(1).split(","):
            i = int(ix)
            if i < len(lhs_dims):
                cprod *= lhs_dims[i]
    return 2.0 * rprod * cprod


_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "call", "after-all", "partition-id",
    "replica-id", "copy-start", "copy-done", "opt-barrier", "domain",
    "get-dimension-size",
}


class HloCost:
    def __init__(self, hlo: str):
        self.comps, self.types = parse_computations(hlo)
        self.flops = 0.0
        self.bytes = 0.0
        self.collective_bytes = 0.0
        self.collective_detail: Dict[str, float] = {}
        self.loops: List[Tuple[str, int]] = []
        if "@entry" in self.comps:
            self._walk("@entry", 1.0, count_bytes=True)

    def _callee(self, attrs: str, key: str) -> Optional[str]:
        m = re.search(key + r"=%?([\w\.\-]+)", attrs)
        return m.group(1) if m else None

    def _io_bytes(self, ins: _Instr, comp: str) -> float:
        """HBM-traffic proxy for one instruction: result + operand bytes,
        with slice-aware corrections:

        * dynamic-slice / slice / gather read only the slice (2x result),
          not the sliced-into buffer (scan reads a [L, ...] weight stack
          one layer at a time — counting the stack per iteration would
          overcount L x);
        * dynamic-update-slice writes only the update (in-place aliasing
          inside loops), so 2 x update-operand bytes;
        * fusion operands > 8x the result are treated as slice-reads of a
          stack/cache and skipped (the slicing happens inside the fusion).
        """
        rb = _all_shape_bytes(ins.result)
        if ins.op in ("dynamic-slice", "slice", "gather"):
            return 2.0 * rb
        ops = _operand_types(ins, self.types.get(comp, {}))
        if ins.op == "dynamic-update-slice":
            upd = _all_shape_bytes(ops[1]) if len(ops) > 1 else rb
            return 2.0 * upd
        if ins.op == "fusion" and "dynamic-update-slice" in ins.name:
            # DUS-rooted fusion: writes only the update slice (the result
            # buffer is aliased in-place) — count the slice-sized
            # operands, not the stack-sized result.
            small = [b for t in ops
                     if (b := _all_shape_bytes(t)) < rb]
            return 2.0 * (sum(small) if small else rb)
        ob = 0.0
        for t in ops:
            b = _all_shape_bytes(t)
            if ins.op == "fusion" and b > 8.0 * max(rb, 1.0):
                continue
            ob += b
        return rb + ob

    def _operand_bytes(self, ins: _Instr, comp: str) -> float:
        ops = _operand_types(ins, self.types.get(comp, {}))
        return sum(_all_shape_bytes(t) for t in ops)

    def _walk(self, comp: str, mult: float, count_bytes: bool):
        for ins in self.comps.get(comp, []):
            op = ins.op
            if op == "while":
                cond = self._callee(ins.attrs, "condition")
                body = self._callee(ins.attrs, "body")
                trip = _trip_count(self.comps.get(cond, [])) if cond else 1
                self.loops.append((body or "?", trip))
                if body:
                    self._walk(body, mult * trip, count_bytes)
                continue
            if op == "conditional":
                for m in re.finditer(r"%?([\w\.\-]+)", ins.attrs):
                    if m.group(1) in self.comps and \
                            "branch" in ins.attrs[:m.start(1)][-40:]:
                        self._walk(m.group(1), mult, count_bytes)
                continue
            if op == "call":
                callee = self._callee(ins.attrs, "to_apply")
                if callee:
                    self._walk(callee, mult, count_bytes)
                continue
            if op == "fusion":
                callee = self._callee(ins.attrs, "calls")
                if callee:
                    self._walk(callee, mult, count_bytes=False)
                if count_bytes:
                    self.bytes += mult * self._io_bytes(ins, comp)
                continue
            base = op[:-6] if op.endswith("-start") else op
            if base in _COLLECTIVES and not op.endswith("-done"):
                factor = 2.0 if base == "all-reduce" else 1.0
                nbytes = mult * factor * self._operand_bytes(ins, comp)
                self.collective_bytes += nbytes
                self.collective_detail[base] = \
                    self.collective_detail.get(base, 0.0) + nbytes
                if count_bytes:
                    self.bytes += mult * self._io_bytes(ins, comp)
                continue
            if op == "dot":
                self.flops += mult * _dot_flops(ins,
                                                self.types.get(comp, {}))
            if count_bytes and op not in _SKIP_BYTES_OPS:
                self.bytes += mult * self._io_bytes(ins, comp)


def collective_bytes(hlo: str) -> Dict[str, float]:
    cost = HloCost(hlo)
    return {"total": cost.collective_bytes, **cost.collective_detail}


def model_flops_per_step(cfg, params_abs, kind: str, global_batch: int,
                         seq: int) -> float:
    """Analytic MODEL_FLOPS: 6*N*D (train) / 2*N_active*D (fwd-only),
    N = active non-embedding params."""
    total = 0
    expert_total = 0
    import jax
    from repro.distributed.sharding import _path_str

    def visit(path, leaf):
        nonlocal total, expert_total
        p = _path_str(path)
        n = 1
        for d in leaf.shape:
            n *= d
        if "embeddings" in p:
            return
        total += n
        if "experts_" in p:
            expert_total += n
    jax.tree_util.tree_map_with_path(visit, params_abs)
    active = total
    if cfg.moe is not None:
        frac = cfg.moe.top_k / cfg.moe.n_experts
        active = total - expert_total + expert_total * frac
    tokens = global_batch * (seq if kind in ("train", "prefill") else 1)
    mult = 6.0 if kind == "train" else 2.0
    return mult * active * tokens


def analyze_compiled(arch, shape, mesh, cfg, compiled, cost, mem, coll,
                     params_abs=None) -> dict:
    """One §Roofline record (all quantities PER DEVICE)."""
    from repro.launch.shapes import SHAPES
    spec = SHAPES[shape]
    hlo_cost = HloCost(compiled.as_text())
    n_dev = 1
    for v in mesh.shape.values():
        n_dev *= v
    if params_abs is None:
        from repro.launch.shapes import abstract_params
        params_abs = abstract_params(cfg)
    mflops = model_flops_per_step(cfg, params_abs, spec.kind,
                                  spec.global_batch, spec.seq)
    flops_dev = hlo_cost.flops
    bytes_dev = hlo_cost.bytes
    coll_dev = hlo_cost.collective_bytes
    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    coll_s = coll_dev / ICI_BW
    dominant = max((("compute", compute_s), ("memory", memory_s),
                    ("collective", coll_s)), key=lambda kv: kv[1])[0]
    mesh_name = "x".join(f"{k}={v}" for k, v in mesh.shape.items())
    return {
        "arch": arch, "shape": shape, "mesh": mesh_name, "devices": n_dev,
        "kind": spec.kind,
        "hlo_flops": flops_dev * n_dev,          # global
        "hlo_bytes": bytes_dev * n_dev,
        "collective_bytes": coll_dev * n_dev,
        "per_device": {"flops": flops_dev, "bytes": bytes_dev,
                       "collective_bytes": coll_dev},
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": coll_s, "dominant": dominant,
        "model_flops": mflops,
        "useful_ratio": mflops / max(flops_dev * n_dev, 1.0),
        "collective_ops": hlo_cost.collective_detail,
        "loops": hlo_cost.loops[:20],
        "xla_cost_analysis": {k: cost.get(k) for k in
                              ("flops", "bytes accessed")} if cost else {},
        "memory_analysis": str(mem)[:400],
    }
