"""Deadline-aware dynamic batching for the IMBUE serving engine.

Individual requests queue up; a batch is cut when either (a) enough
requests are waiting to fill the largest bucket, or (b) the oldest
request's batching deadline expires.  Cut batches are padded up to the
smallest *bucket* that fits — buckets are the Pallas batch-tile sizes
(multiples of the f32 sublane count, capped at the ``BT = 128`` MXU tile
of ``kernels/imbue_infer.py``) so every bucket maps to a compiled kernel
shape and the jit cache stays bounded at ``len(bucket_sizes)`` entries
per replica-role.

Bucket ladders come from one of two places: an explicit
``bucket_sizes`` tuple, or — when the config was built by
:meth:`BatcherConfig.for_max_batch` (``auto_tune=True``) — the measured
per-backend tuning table in the capability registry
(``kernels/autotune.py``), which the engine installs at construction
(``tuned_for`` records the backend the ladder was measured for).

The batcher owns the **wire format**: in packed mode (the packed_io
backends) each request's Boolean features are packed ONCE at submit time
into the uint32 literal bitplane (``[ceil(2F/32)]`` words), so the queue
and every host->device transfer carry 32x less than f32 (8x less than
uint8) per literal.  Padding rows are zeros — a zero-packed row is a
valid "all literals 0" input, and pad results are dropped on unpad
(asserted), so a kernel bug can never silently alias a real request's
prediction.

**QoS classes** (ISSUE 10): every request carries a class — ``latency``
or ``bulk``.  The batcher keeps one FIFO queue per class and never mixes
classes in a batch: latency requests get a shorter batching deadline
(``latency_max_wait_s``, default ``max_wait_s / 4``) so they cut small
batches early, while bulk requests wait the full ``max_wait_s`` to ride
the largest bucket.  Cut priority goes to the latency class, but only
among *ready* queues — a ready bulk queue is cut on the very next pump
after its own deadline fires, so early latency cuts can delay bulk by at
most one dispatch, never starve it.  Admission control is also
per-class: ``queue_depth_for`` bounds each class independently on top of
the engine-level global depth.
"""

from __future__ import annotations

import bisect
import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.kernels.bitpack import WORD, words_for

STATIC_BUCKETS = (8, 16, 32, 64, 128)     # pre-autotuning fallback ladder

# QoS classes.  ``latency`` cuts early and is popped first among ready
# queues; ``bulk`` (the default, and the behaviour of every pre-QoS
# engine) waits out the full batching deadline to fill large buckets.
QOS_LATENCY = "latency"
QOS_BULK = "bulk"
QOS_CLASSES: Tuple[str, ...] = (QOS_LATENCY, QOS_BULK)


def validate_qos(qos: str) -> str:
    if qos not in QOS_CLASSES:
        raise ValueError(f"unknown QoS class {qos!r}; expected one of "
                         f"{QOS_CLASSES}")
    return qos


class QueueFull(RuntimeError):
    """Typed admission-control rejection (ISSUE 8): raised by
    ``ServeEngine.submit`` when ``EngineConfig.max_queue_depth`` queued
    requests are already waiting, or (ISSUE 10) when the request's QoS
    class is at its per-class depth limit / a ``StreamServer`` is at
    ``max_sessions``.  Callers catch it to shed load or retry after a
    ``pump()``; every raise is metered (``summary()['rejected']``)."""


class NonBooleanInput(ValueError):
    """Typed rejection for request features outside {0, 1}.

    ``pack_request_np`` builds the complement plane with
    ``np.subtract(1, x)`` in uint8, which WRAPS for ``x > 1`` (x=2 ->
    255) so after packbits both the literal and its complement read as
    1 — silent corruption.  Instead of thresholding (which would make
    packed and unpacked paths disagree), non-Boolean inputs are rejected
    at submit on BOTH paths with this error.
    """


def _check_boolean(x: np.ndarray) -> None:
    """Reject features outside {0, 1} before they hit the wire format."""
    if x.size and ((x != 0) & (x != 1)).any():
        bad = x[(x != 0) & (x != 1)].flat[0]
        raise NonBooleanInput(
            f"request features must be Boolean (0/1); got value {bad!r} — "
            "booleanize inputs (repro.data.booleanize) before submit")


def pack_request_np(x: np.ndarray) -> np.ndarray:
    """``[F]`` Boolean features -> ``[ceil(2F/32)]`` uint32 literal words.

    Builds the literal vector (features then complements, matching
    ``repro.core.tm.literals``) and packs it host-side — called once per
    request at submit, never per dispatch, so it is written to minimize
    per-call temporaries (one zeroed word-aligned buffer, one packbits).
    Raises :class:`NonBooleanInput` for values outside {0, 1}: the uint8
    complement ``1 - x`` wraps for x > 1, which would silently pack both
    planes as 1.
    """
    arr = np.asarray(x)
    _check_boolean(arr)
    x = arr.astype(np.uint8, copy=False)
    f = x.shape[-1]
    buf = np.zeros(words_for(2 * f) * WORD, dtype=np.uint8)  # pad bits = 0
    buf[:f] = x
    np.subtract(1, x, out=buf[f:2 * f])
    return np.packbits(buf, bitorder="little").view("<u4")


@dataclasses.dataclass(frozen=True)
class BatcherConfig:
    """Knobs for the dynamic batcher."""

    max_batch: int = 128                # largest bucket == Pallas BT tile
    max_wait_s: float = 2e-3            # batching deadline (bulk class)
    bucket_sizes: Tuple[int, ...] = STATIC_BUCKETS
    # True -> the engine may replace bucket_sizes with the measured
    # per-backend ladder from the registry tuning table (set by
    # for_max_batch; explicit bucket_sizes constructions keep theirs).
    auto_tune: bool = False
    # Name of the backend whose measured table produced bucket_sizes
    # (None for the static/hand-picked ladder).
    tuned_for: Optional[str] = None
    # Batching deadline for the latency class.  None -> max_wait_s / 4:
    # latency requests cut (small) batches early instead of waiting to
    # fill the big bucket.  Bulk always uses max_wait_s.
    latency_max_wait_s: Optional[float] = None
    # Per-class admission depth limits (None = only the engine-level
    # global max_queue_depth applies).  A full class rejects with
    # QueueFull naming the class, without touching the other class.
    latency_queue_depth: Optional[int] = None
    bulk_queue_depth: Optional[int] = None

    def __post_init__(self):
        sizes = tuple(sorted(self.bucket_sizes))
        object.__setattr__(self, "bucket_sizes", sizes)
        if not sizes:
            raise ValueError("need at least one bucket size")
        if sizes[-1] != self.max_batch:
            raise ValueError(
                f"largest bucket {sizes[-1]} must equal max_batch "
                f"{self.max_batch}")
        if any(s % 8 for s in sizes):
            raise ValueError("bucket sizes must be multiples of the f32 "
                             "sublane count (8) for TPU tiling")
        if self.latency_max_wait_s is not None and \
                self.latency_max_wait_s <= 0:
            raise ValueError("latency_max_wait_s must be positive")
        for name in ("latency_queue_depth", "bulk_queue_depth"):
            v = getattr(self, name)
            if v is not None and v < 1:
                raise ValueError(f"{name} must be >= 1")

    @classmethod
    def for_max_batch(cls, max_batch: int, **kw) -> "BatcherConfig":
        """Standard tile buckets up to ``max_batch`` (itself the top
        bucket, so any multiple of 8 up to 128 is a valid max).  Marks
        the config ``auto_tune`` so the engine swaps in the measured
        per-backend ladder once the backend is known."""
        buckets = tuple(b for b in STATIC_BUCKETS if b < max_batch)
        return cls(max_batch=max_batch,
                   bucket_sizes=buckets + (max_batch,), auto_tune=True,
                   **kw)

    def with_tuned_buckets(self, bucket_sizes: Sequence[int],
                           backend: str) -> "BatcherConfig":
        """This config with the measured ladder (capped at max_batch)."""
        tuned = tuple(b for b in sorted(bucket_sizes) if b < self.max_batch)
        return dataclasses.replace(self,
                                   bucket_sizes=tuned + (self.max_batch,),
                                   tuned_for=backend)

    def bucket_for(self, n: int) -> int:
        """Smallest bucket holding ``n`` requests."""
        i = bisect.bisect_left(self.bucket_sizes, n)
        if i == len(self.bucket_sizes):
            raise ValueError(f"batch of {n} exceeds max_batch "
                             f"{self.max_batch}")
        return self.bucket_sizes[i]

    def wait_for(self, qos: str) -> float:
        """Batching deadline for ``qos`` relative to submit time."""
        if qos == QOS_LATENCY:
            return (self.max_wait_s / 4 if self.latency_max_wait_s is None
                    else self.latency_max_wait_s)
        return self.max_wait_s

    def queue_depth_for(self, qos: str) -> Optional[int]:
        """Per-class admission depth limit (None = unbounded)."""
        return (self.latency_queue_depth if qos == QOS_LATENCY
                else self.bulk_queue_depth)


@dataclasses.dataclass
class Request:
    """One queued inference request."""

    rid: int
    # [F] uint8 features, or [Lw] uint32 packed literal words (packed mode)
    x: np.ndarray
    t_enqueue: float
    deadline: float                     # absolute batching deadline
    # Absolute REQUEST deadline (ISSUE 8): past this instant a
    # still-queued request must not be dispatched — the engine reaps it
    # into an ``expired=True`` Response.  None = never expires.  The
    # batching ``deadline`` above shapes batch cutting; this one is a
    # client SLO.
    expiry: Optional[float] = None
    qos: str = QOS_BULK


@dataclasses.dataclass
class Batch:
    """A cut batch, padded to a bucketed kernel shape."""

    requests: List[Request]
    x: np.ndarray                       # [bucket, F] uint8 | [bucket, Lw] u32
    bucket: int
    packed: bool = False
    # Host time spent assembling this batch's operand (stack + pad) —
    # the per-dispatch "host pack" half of the overlap accounting.
    pack_s: float = 0.0
    qos: str = QOS_BULK                 # batches never mix QoS classes

    @property
    def n_valid(self) -> int:
        return len(self.requests)

    @property
    def n_padding(self) -> int:
        return self.bucket - len(self.requests)

    @property
    def nbytes(self) -> int:
        """Bytes this batch moves host->device per dispatch."""
        return int(self.x.nbytes)


class DynamicBatcher:
    """Per-QoS-class FIFO queues with deadline/size-triggered cutting.

    One deque per class; batches never mix classes.  All cut paths —
    ``cut`` with or without ``force`` — first move already-expired
    requests into an internal outbox drained by :meth:`reap_expired`, so
    a ``drain()`` can never dispatch a request whose client SLO has
    already passed.
    """

    def __init__(self, cfg: BatcherConfig = BatcherConfig(), *,
                 packed: bool = False):
        self.cfg = cfg
        self.packed = packed
        self._queues: Dict[str, Deque[Request]] = {
            q: deque() for q in QOS_CLASSES}
        self._expired_outbox: List[Request] = []

    def __len__(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def depth(self, qos: str) -> int:
        """Queued requests in one QoS class."""
        return len(self._queues[validate_qos(qos)])

    def submit(self, rid: int, x: np.ndarray, now: float,
               deadline_s: Optional[float] = None,
               qos: str = QOS_BULK) -> Request:
        """Queue one request; in packed mode the features are packed to
        literal words HERE (once), not at dispatch.  ``deadline_s`` is
        the request's expiry relative to ``now`` (see
        :attr:`Request.expiry`).  Raises :class:`NonBooleanInput` for
        features outside {0, 1} on both wire formats."""
        validate_qos(qos)
        if self.packed:
            row = pack_request_np(x)
        else:
            arr = np.asarray(x)
            _check_boolean(arr)
            row = arr.astype(np.uint8, copy=False)
        req = Request(rid=rid, x=row, t_enqueue=now,
                      deadline=now + self.cfg.wait_for(qos),
                      expiry=None if deadline_s is None
                      else now + deadline_s,
                      qos=qos)
        self._queues[qos].append(req)
        return req

    def _reap_into_outbox(self, now: float) -> None:
        """Move already-expired queued requests into the outbox (queue
        order of survivors preserved).  Called by every cut path so no
        cut — forced or not — can dispatch a request past its expiry."""
        for qos, q in self._queues.items():
            if any(r.expiry is not None and now >= r.expiry for r in q):
                self._expired_outbox.extend(
                    r for r in q if r.expiry is not None and now >= r.expiry)
                self._queues[qos] = deque(
                    r for r in q if r.expiry is None or now < r.expiry)

    def reap_expired(self, now: float) -> List[Request]:
        """Remove and return every queued request whose expiry has
        passed (including any a cut path already set aside).  A request
        already cut into a batch can no longer expire (dispatch wins
        races by design — the deadline guards *queue* time)."""
        self._reap_into_outbox(now)
        expired, self._expired_outbox = self._expired_outbox, []
        return expired

    def _ready_class(self, now: float) -> Optional[str]:
        """First class (latency priority) that is ready to cut: its
        queue fills the largest bucket, or its oldest request has hit
        its batching deadline."""
        for qos in QOS_CLASSES:            # latency first
            q = self._queues[qos]
            if q and (len(q) >= self.cfg.max_batch
                      or now >= q[0].deadline):
                return qos
        return None

    def ready(self, now: float) -> bool:
        """A batch should be cut from some class."""
        return self._ready_class(now) is not None

    def next_deadline(self) -> Optional[float]:
        heads = [q[0].deadline for q in self._queues.values() if q]
        return min(heads) if heads else None

    def cut(self, now: float, force: bool = False) -> Optional[Batch]:
        """Pop up to ``max_batch`` requests (FIFO, one class) into a
        padded batch.  Expired requests are reaped first — a forced
        drain returns them via :meth:`reap_expired`, never in a batch."""
        self._reap_into_outbox(now)
        qos = self._ready_class(now)
        if qos is None:
            if not force:
                return None
            qos = next((c for c in QOS_CLASSES if self._queues[c]), None)
            if qos is None:
                return None
        q = self._queues[qos]
        take = min(len(q), self.cfg.max_batch)
        reqs = [q.popleft() for _ in range(take)]
        return self.pad(reqs)

    def pad(self, reqs: Sequence[Request]) -> Batch:
        t0 = time.perf_counter()
        bucket = self.cfg.bucket_for(len(reqs))
        x = np.stack([r.x for r in reqs])
        if bucket > len(reqs):
            # Zero rows, NOT a replay of a real request: a pad row that
            # leaks through unpad must surface as an obviously-wrong
            # all-zero input rather than duplicating request 0's answer.
            fill = np.zeros((bucket - len(reqs), x.shape[1]), dtype=x.dtype)
            x = np.concatenate([x, fill], axis=0)
        return Batch(requests=list(reqs), x=np.ascontiguousarray(x),
                     bucket=bucket, packed=self.packed,
                     pack_s=time.perf_counter() - t0,
                     qos=reqs[0].qos if reqs else QOS_BULK)
