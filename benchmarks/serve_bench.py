"""Serving-engine benchmark: dynamic batching x replica-pool sweep.

Measures the simulator's serving throughput/latency across
(max-batch, replica-count) configurations and against the seed's
per-request serial path (one kernel dispatch per request — what
``launch/serve.py`` did before the engine existed).  Writes
``BENCH_serve.json`` next to the repo root.

Interpret-mode Pallas on CPU means absolute numbers are simulator
figures, not hardware ones; the hardware figures of merit are reported
separately by ``repro.serve.metrics.hardware_figures``.  The quantity
that transfers is the *relative* win of batching: per-dispatch overhead
is amortized over the bucket, exactly as a real accelerator amortizes
launch + DMA cost.

  PYTHONPATH=src python -m benchmarks.serve_bench [--requests 192]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tm import TMConfig
from repro.core.variations import VariationConfig
from repro.serve import BatcherConfig, EngineConfig, ServeEngine

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def make_model(key):
    """Small trained-free TM (sparse random includes) — the bench measures
    serving mechanics, not accuracy."""
    cfg = TMConfig(n_classes=4, clauses_per_class=8, n_features=64,
                   n_states=100)
    inc = jax.random.bernoulli(key, 0.1, (cfg.n_clauses, cfg.n_literals))
    ta = jnp.where(inc, cfg.n_states + 1, cfg.n_states).astype(
        cfg.state_dtype)
    return cfg, ta


def make_engine(cfg, ta, *, max_batch, n_replicas, routing="round_robin"):
    # CSA offset off so serving stays on the fused Pallas kernel path
    # (the offset is only modeled by the jnp path; see EngineConfig).
    return ServeEngine.from_ta_state(
        ta, cfg, n_replicas=n_replicas, key=jax.random.PRNGKey(3),
        vcfg=VariationConfig(csa_offset=False),
        ecfg=EngineConfig(batcher=BatcherConfig.for_max_batch(max_batch),
                          routing=routing))


def run_batched(cfg, ta, xs, *, max_batch, n_replicas, routing):
    """Submit everything, then drain: batches cut at ``max_batch``."""
    engine = make_engine(cfg, ta, max_batch=max_batch,
                         n_replicas=n_replicas, routing=routing)
    engine.submit_many([xs[0]] * max_batch)   # warm the kernel cache
    engine.drain()
    engine.metrics = type(engine.metrics)()
    t0 = time.monotonic()
    engine.submit_many(list(xs))
    engine.drain()
    wall = time.monotonic() - t0
    out = engine.summary()
    out["wall_s"] = wall
    out["wall_throughput_rps"] = len(xs) / wall
    out["max_batch"] = max_batch
    return out


def run_serial(cfg, ta, xs, *, n_replicas=1):
    """The seed's per-request path: one dispatch per request."""
    engine = make_engine(cfg, ta, max_batch=8, n_replicas=n_replicas)
    engine.submit(xs[0])
    engine.drain()                             # warm the bucket-8 kernel
    engine.metrics = type(engine.metrics)()
    t0 = time.monotonic()
    for x in xs:
        engine.submit(x)
        engine.drain()                         # force: batch of 1, now
    wall = time.monotonic() - t0
    out = engine.summary()
    out["wall_s"] = wall
    out["wall_throughput_rps"] = len(xs) / wall
    out["max_batch"] = 1
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=192,
                    help="requests per batched configuration")
    ap.add_argument("--serial-requests", type=int, default=48,
                    help="requests for the serial baseline (slow path)")
    ap.add_argument("--out", default=os.path.join(REPO, "BENCH_serve.json"))
    args = ap.parse_args(argv)

    cfg, ta = make_model(jax.random.PRNGKey(0))
    xs = np.asarray(jax.random.bernoulli(
        jax.random.PRNGKey(1), 0.4,
        (args.requests, cfg.n_features))).astype(np.uint8)

    print("[serve_bench] serial baseline (per-request dispatch)...")
    serial = run_serial(cfg, ta, xs[:args.serial_requests])
    print(f"[serve_bench]   serial: "
          f"{serial['wall_throughput_rps']:.1f} req/s")

    sweep = []
    for n_replicas in (1, 2, 4):
        for max_batch in (8, 32, 64):
            row = run_batched(cfg, ta, xs, max_batch=max_batch,
                              n_replicas=n_replicas,
                              routing="round_robin")
            row["speedup_vs_serial"] = (row["wall_throughput_rps"]
                                        / serial["wall_throughput_rps"])
            sweep.append(row)
            print(f"[serve_bench]   R={n_replicas} batch={max_batch}: "
                  f"{row['wall_throughput_rps']:.1f} req/s "
                  f"({row['speedup_vs_serial']:.1f}x serial), "
                  f"p99 {row['p99_ms']:.1f} ms")
    ens = run_batched(cfg, ta, xs, max_batch=64, n_replicas=4,
                      routing="ensemble")
    ens["speedup_vs_serial"] = (ens["wall_throughput_rps"]
                                / serial["wall_throughput_rps"])
    print(f"[serve_bench]   ensemble R=4 batch=64: "
          f"{ens['wall_throughput_rps']:.1f} req/s")

    at64 = [r for r in sweep
            if r["max_batch"] == 64 and r["n_replicas"] == 1]
    speedup64 = at64[0]["speedup_vs_serial"]
    report = {
        "model": {"n_clauses": cfg.n_clauses,
                  "n_literals": cfg.n_literals,
                  "n_classes": cfg.n_classes},
        "backend": jax.default_backend(),
        "requests": args.requests,
        "serial_baseline": serial,
        "sweep": sweep,
        "ensemble": ens,
        "speedup_batch64_vs_serial": speedup64,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2, default=str)
    print(f"[serve_bench] wrote {args.out}")
    print(f"[serve_bench] dynamic batching at 64: "
          f"{speedup64:.1f}x the serial path "
          f"({'PASS' if speedup64 >= 1.5 else 'FAIL'} >= 1.5x)")
    return report


if __name__ == "__main__":
    main()
