import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes, without allocating a single parameter.

For each cell we build the real step function (train_step with optimizer,
prefill forward, or decode_step), jit it with full in/out shardings, and
``.lower().compile()`` against ShapeDtypeStruct inputs on:

  * single-pod mesh (16 x 16 = 256 chips), and
  * multi-pod mesh (2 x 16 x 16 = 512 chips).

The compiled artifact's ``memory_analysis()`` / ``cost_analysis()`` plus
our HLO collective-byte parse are recorded to JSON for §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-0.5b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
      --out dryrun_results.json
"""

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.distributed import sharding as shd  # noqa: E402
from repro.launch import shapes as shp  # noqa: E402
from repro.launch.mesh import make_production_mesh, rules_for  # noqa: E402
from repro.models import transformer as tf  # noqa: E402
from repro.optim.optimizers import OptimizerConfig, make_optimizer  # noqa: E402
from repro.roofline.analysis import (analyze_compiled,  # noqa: E402
                                     collective_bytes, xla_cost_dict)
from repro.train.train_step import make_train_step  # noqa: E402


def optimizer_for(cfg):
    """Arch-appropriate optimizer: 480B-class uses Adafactor with bf16
    momentum (memory fit, DESIGN.md §6), everything else AdamW."""
    if cfg.param_dtype == "bfloat16":
        return make_optimizer(OptimizerConfig(
            name="adafactor", state_dtype="bfloat16"))
    return make_optimizer(OptimizerConfig(name="adamw"))


def lower_cell(arch: str, shape: str, mesh, *, verbose=True):
    """Lower+compile one cell on ``mesh``; returns the result record."""
    cfg = shp.cell_config(arch, shape)
    spec = shp.SHAPES[shape]
    rules = rules_for(cfg, mesh, global_batch=spec.global_batch,
                      pure_dp=(arch in shp.PURE_DP_ARCHS
                               and spec.kind == "train"))
    params_abs = shp.abstract_params(cfg)
    p_sh = shd.tree_shardings(params_abs, mesh, rules)
    t0 = time.time()

    with shd.use_sharding(mesh, rules):
        if spec.kind == "train":
            opt = optimizer_for(cfg)
            opt_abs = jax.eval_shape(opt.init, params_abs)
            o_sh = shd.tree_shardings(opt_abs, mesh, rules)
            batch_abs = shp.input_specs(cfg, shape)
            b_sh = {k: NamedSharding(mesh, P(rules.batch))
                    for k in batch_abs}
            import jax.numpy as _jnp
            mb = shp.TRAIN_MICROBATCHES.get(arch, 1)
            step = make_train_step(
                cfg, opt, microbatches=mb,
                accum_dtype=_jnp.bfloat16
                if cfg.param_dtype == "bfloat16" else _jnp.float32)
            jitted = jax.jit(
                step,
                in_shardings=(p_sh, o_sh, None, b_sh),
                out_shardings=(p_sh, o_sh, None),
                donate_argnums=(0, 1))
            lowered = jitted.lower(
                params_abs, opt_abs,
                jax.ShapeDtypeStruct((), jnp.int32), batch_abs)
        elif spec.kind == "prefill":
            batch_abs = shp.input_specs(cfg, shape)
            b_sh = {k: NamedSharding(mesh, P(rules.batch))
                    for k in batch_abs}

            def prefill(params, batch):
                return tf.forward(params, batch, cfg, last_only=True)

            jitted = jax.jit(prefill, in_shardings=(p_sh, b_sh),
                             out_shardings=None)
            lowered = jitted.lower(params_abs, batch_abs)
        else:   # decode
            state_abs = shp.abstract_decode_state(cfg, shape)
            s_sh = shd.cache_shardings(state_abs, mesh, rules,
                                       spec.global_batch, spec.seq)
            ins = shp.input_specs(cfg, shape)
            tok_sh = NamedSharding(
                mesh, P(rules.batch if spec.global_batch > 1 else None,
                        None))

            def serve_step(params, state, token, pos):
                return tf.decode_step(params, state, token, pos, cfg)

            jitted = jax.jit(serve_step,
                             in_shardings=(p_sh, s_sh, tok_sh, None),
                             out_shardings=(None, s_sh),
                             donate_argnums=(1,))
            lowered = jitted.lower(params_abs, state_abs, ins["token"],
                                   ins["pos"])

        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = xla_cost_dict(compiled.cost_analysis())
    coll = collective_bytes(compiled.as_text())
    rec = analyze_compiled(arch, shape, mesh, cfg, compiled, cost, mem,
                           coll)
    rec["lower_s"] = round(t_lower, 1)
    rec["compile_s"] = round(t_compile, 1)
    if verbose:
        print(f"  memory_analysis: {mem}")
        print(f"  flops={rec['hlo_flops']:.3e} bytes={rec['hlo_bytes']:.3e}"
              f" collective_bytes={rec['collective_bytes']:.3e}")
        print(f"  lower {t_lower:.1f}s compile {t_compile:.1f}s")
    return rec


TM_SHAPES = {"tm_train_4k": ("train", 4096),
             "tm_infer_32k": ("infer", 32768),
             "imbue_infer_32k": ("analog", 32768)}
TM_CELL_ARCHS = ["imbue-tm-mnist", "imbue-tm-fmnist"]


def lower_tm_cell(arch: str, shape: str, mesh, *, verbose=True):
    """The paper's TM workload through the same dry-run machinery."""
    from repro.configs.imbue_tm import tm_config
    from repro.core import tm_distributed as tmd
    from repro.roofline.analysis import (HBM_BW, ICI_BW, PEAK_FLOPS,
                                         HloCost)

    cfg = tmd.pad_clauses_for_mesh(tm_config(arch), mesh)
    kind, batch = TM_SHAPES[shape]
    st_sh, x_sh, y_sh = tmd.tm_shardings(cfg, mesh, batch)
    c, l = cfg.n_clauses, cfg.n_literals
    x_abs = jax.ShapeDtypeStruct((batch, cfg.n_features), jnp.uint8)
    t0 = time.time()
    if kind == "train":
        st_abs = jax.ShapeDtypeStruct((c, l), jnp.int16)
        y_abs = jax.ShapeDtypeStruct((batch,), jnp.int32)
        k_abs = jax.ShapeDtypeStruct((2,), jnp.uint32)

        def step(st, key, x, y):
            return tmd.tm_train_step(st, key, x, y, cfg)

        jitted = jax.jit(step, in_shardings=(st_sh, None, x_sh, y_sh),
                         out_shardings=st_sh, donate_argnums=(0,))
        lowered = jitted.lower(st_abs, k_abs, x_abs, y_abs)
        mult, active = 4.0, 1.0   # fwd eval + delta passes (analytic)
    elif kind == "infer":
        st_abs = jax.ShapeDtypeStruct((c, l), jnp.int16)
        jitted = jax.jit(lambda st, x: tmd.tm_infer_step(st, x, cfg),
                         in_shardings=(st_sh, x_sh), out_shardings=y_sh)
        lowered = jitted.lower(st_abs, x_abs)
        mult, active = 2.0, 1.0
    else:   # analog
        from repro.core.imbue import IMBUEConfig
        g_abs = jax.ShapeDtypeStruct((c, l), jnp.float32)
        inc_abs = jax.ShapeDtypeStruct((c, l), jnp.bool_)
        # Electrical constants come from the unified-backend config (the
        # same IMBUEConfig that repro.api.CrossbarState carries as
        # aux_data), not a hand-copied literal.
        icfg = IMBUEConfig()

        def step(g_on, i_leak, inc, x):
            return tmd.imbue_infer_step(
                g_on, i_leak, inc, x, cfg, v_read=icfg.v_read,
                r_div=icfg.r_divider, v_ref=icfg.reference_voltage())

        jitted = jax.jit(step, in_shardings=(st_sh, st_sh, st_sh, x_sh),
                         out_shardings=y_sh)
        lowered = jitted.lower(g_abs, g_abs, inc_abs, x_abs)
        mult, active = 4.0, 1.0   # on-path + leak-path matmuls
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    hc = HloCost(compiled.as_text())
    n_dev = 1
    for v in mesh.shape.values():
        n_dev *= v
    model_flops = mult * batch * c * l * active
    compute_s = hc.flops / PEAK_FLOPS
    memory_s = hc.bytes / HBM_BW
    coll_s = hc.collective_bytes / ICI_BW
    dominant = max((("compute", compute_s), ("memory", memory_s),
                    ("collective", coll_s)), key=lambda kv: kv[1])[0]
    rec = {
        "arch": arch, "shape": shape,
        "mesh": "x".join(f"{k}={v}" for k, v in mesh.shape.items()),
        "devices": n_dev, "kind": f"tm_{kind}",
        "hlo_flops": hc.flops * n_dev, "hlo_bytes": hc.bytes * n_dev,
        "collective_bytes": hc.collective_bytes * n_dev,
        "per_device": {"flops": hc.flops, "bytes": hc.bytes,
                       "collective_bytes": hc.collective_bytes},
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": coll_s, "dominant": dominant,
        "model_flops": model_flops,
        "useful_ratio": model_flops / max(hc.flops * n_dev, 1.0),
        "collective_ops": hc.collective_detail,
        "loops": hc.loops[:10],
        "memory_analysis": str(compiled.memory_analysis())[:400],
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
    }
    if verbose:
        print(f"  flops={rec['hlo_flops']:.3e} bytes={rec['hlo_bytes']:.3e}"
              f" collective_bytes={rec['collective_bytes']:.3e}"
              f" dominant={dominant}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--tm", action="store_true",
                    help="include the paper's TM cells")
    ap.add_argument("--out", default="dryrun_results.json")
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    if args.all:
        todo = [(a, s) for a, s, ok, _ in shp.cells() if ok]
        if args.tm:
            todo += [(a, s) for a in TM_CELL_ARCHS for s in TM_SHAPES]
    else:
        todo = [(args.arch, args.shape)]

    results, failures = [], []
    for multi in meshes:
        mesh = make_production_mesh(multi_pod=multi)
        mname = "multi(2x16x16)" if multi else "single(16x16)"
        for arch, shape in todo:
            print(f"[dryrun] {arch} x {shape} on {mname}", flush=True)
            try:
                if arch.startswith("imbue-tm"):
                    rec = lower_tm_cell(arch, shape, mesh)
                else:
                    rec = lower_cell(arch, shape, mesh)
                results.append(rec)
            except Exception as e:  # noqa: BLE001
                traceback.print_exc()
                failures.append({"arch": arch, "shape": shape,
                                 "mesh": mname, "error": str(e)[:500]})
    skipped = [{"arch": a, "shape": s, "reason": why}
               for a, s, ok, why in shp.cells(include_skipped=True)
               if not ok]
    with open(args.out, "w") as f:
        json.dump({"results": results, "failures": failures,
                   "skipped": skipped}, f, indent=1)
    print(f"\n{len(results)} cells compiled, {len(failures)} failures, "
          f"{len(skipped)} skipped-by-rule -> {args.out}")
    if failures:
        for f_ in failures:
            print("FAIL:", f_["arch"], f_["shape"], f_["mesh"],
                  f_["error"][:200])
        raise SystemExit(1)


if __name__ == "__main__":
    main()
