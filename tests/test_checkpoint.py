"""Checkpoint restore edge cases (ISSUE 8).

``distributed/checkpoint.py``'s restore path distinguishes its three
corruption modes with typed errors under one ``CheckpointError`` base,
each also inheriting the builtin the pre-typed code raised — so both
the new precise handlers and legacy ``except FileNotFoundError`` /
``pytest.raises(ValueError, match="digest")`` call sites work.  (The
mesh-dependent save/restore round-trips live in ``test_distributed.py``;
these tests are single-process and run in tier 1.)
"""

import json
import os

import numpy as np
import pytest

from repro.distributed.checkpoint import (CheckpointDigestError,
                                          CheckpointError,
                                          CheckpointManifestError,
                                          CheckpointMissingError, restore,
                                          save)

TREE = {"w": np.arange(6, dtype=np.float32).reshape(2, 3),
        "b": np.ones(3, dtype=np.int32)}


@pytest.fixture
def ckpt(tmp_path):
    d = str(tmp_path / "ckpt")
    path = save(d, 3, TREE)
    return d, path


def test_clean_restore_roundtrip(ckpt):
    d, _ = ckpt
    tree, manifest = restore(d, 3, TREE)
    np.testing.assert_array_equal(np.asarray(tree["w"]), TREE["w"])
    assert manifest["step"] == 3


def test_missing_array_blob(ckpt):
    d, path = ckpt
    os.remove(os.path.join(path, "leaves.npz"))
    with pytest.raises(CheckpointMissingError, match="array blob"):
        restore(d, 3, TREE)
    with pytest.raises(FileNotFoundError):    # legacy except clauses
        restore(d, 3, TREE)
    with pytest.raises(CheckpointError):      # umbrella
        restore(d, 3, TREE)


def test_missing_manifest(ckpt):
    d, path = ckpt
    os.remove(os.path.join(path, "manifest.json"))
    with pytest.raises(CheckpointMissingError, match="manifest"):
        restore(d, 3, TREE)


def test_truncated_manifest(ckpt):
    d, path = ckpt
    mp = os.path.join(path, "manifest.json")
    with open(mp) as f:
        blob = f.read()
    with open(mp, "w") as f:
        f.write(blob[:len(blob) // 2])        # cut mid-JSON
    with pytest.raises(CheckpointManifestError, match="truncated"):
        restore(d, 3, TREE)
    with pytest.raises(ValueError):           # legacy except clauses
        restore(d, 3, TREE)
    with pytest.raises(CheckpointError):
        restore(d, 3, TREE)


def test_digest_mismatch(ckpt):
    d, path = ckpt
    lp = os.path.join(path, "leaves.npz")
    with np.load(lp) as z:
        arrays = {k: z[k].copy() for k in z.files}
    arrays["w"][0, 0] += 1                    # single bit-rotted leaf
    np.savez(lp, **arrays)
    with pytest.raises(CheckpointDigestError):
        restore(d, 3, TREE)
    with pytest.raises(ValueError, match="digest"):   # legacy idiom
        restore(d, 3, TREE)
    with pytest.raises(CheckpointError):
        restore(d, 3, TREE)


def test_error_types_are_distinct(ckpt):
    """The three modes are catchable separately: a digest handler must
    not swallow a missing-file error and vice versa."""
    assert not issubclass(CheckpointMissingError, ValueError)
    assert not issubclass(CheckpointDigestError, FileNotFoundError)
    assert not issubclass(CheckpointManifestError, CheckpointDigestError)
    d, path = ckpt
    os.remove(os.path.join(path, "leaves.npz"))
    with pytest.raises(CheckpointError) as ei:
        restore(d, 3, TREE)
    assert type(ei.value) is CheckpointMissingError


def test_pre_digest_checkpoints_still_restore(ckpt):
    """A manifest written before the digest existed (no content_digest
    key) restores without verification — forward compat is explicit."""
    d, path = ckpt
    mp = os.path.join(path, "manifest.json")
    with open(mp) as f:
        manifest = json.load(f)
    manifest["extra"].pop("content_digest")
    with open(mp, "w") as f:
        json.dump(manifest, f)
    tree, _ = restore(d, 3, TREE)
    np.testing.assert_array_equal(np.asarray(tree["b"]), TREE["b"])
