"""Bit-packed Boolean planes: the native wire format of the inference stack.

IMBUE's premise is that inference stays in the Boolean domain — literals
are digital voltages, include bits are programmed cells — yet shipping
them as float32 (or even uint8) inflates memory traffic 32x (8x) for
data that is one bit wide.  This module is the single source of truth
for the packed representation used end-to-end:

* **layout** — little-endian within each ``uint32`` word: bit ``j`` of
  word ``w`` is Boolean element ``32*w + j``.  Ragged lengths are
  zero-padded up to the word boundary (padding bits are 0, which every
  consumer treats as "excluded literal / excluded cell").
* :func:`pack_bits` / :func:`unpack_bits` — device-side (jnp) pack and
  unpack, shape ``[..., L] <-> [..., ceil(L/32)]``.
* :func:`pack_bits_np` — host-side ``np.packbits`` path (used by the
  serving batcher once per request at submit time, so the queue and the
  host->device transfer carry ``uint32`` words, not bytes).
* :func:`unpack_words_f32` — the in-kernel unpack used by the Pallas
  packed kernels: one ``[bt, kw]`` word block -> ``[bt, 32*kw]`` f32
  bits in VMEM, right before the violation matmul.
* :func:`unpack_words_f32_cols` — the column-axis twin used by the
  plane-packed analog kernels: one ``[kw, ct]`` include-index word
  block -> ``[32*kw, ct]`` f32 bits, i.e. the transposed-plane layout
  the conductance reconstruction consumes.

The layouts of the np and jnp packers are asserted identical by the
round-trip tests (``tests/test_packed*.py``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

WORD = 32                      # bits per packed word (uint32 lanes)


def words_for(n_bits: int) -> int:
    """Number of uint32 words holding ``n_bits`` booleans."""
    return -(-n_bits // WORD)


def pack_bits(bits: jax.Array) -> jax.Array:
    """``[..., L]`` 0/1 -> ``[..., ceil(L/32)] uint32`` (little-endian).

    Accepts any integer/bool dtype; values must be 0/1.  Ragged ``L`` is
    zero-padded to the word boundary.
    """
    bits = jnp.asarray(bits)
    l = bits.shape[-1]
    nw = words_for(l)
    pad = nw * WORD - l
    b = bits.astype(jnp.uint32)
    if pad:
        pads = [(0, 0)] * (b.ndim - 1) + [(0, pad)]
        b = jnp.pad(b, pads)
    b = b.reshape(*bits.shape[:-1], nw, WORD)
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    # bits are disjoint across the shift axis, so sum == bitwise OR
    return jnp.sum(b << shifts, axis=-1, dtype=jnp.uint32)


def unpack_bits(words: jax.Array, n_bits: int) -> jax.Array:
    """``[..., W] uint32`` -> ``[..., n_bits] uint8`` (inverse of pack)."""
    words = jnp.asarray(words, jnp.uint32)
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    bits = (words[..., :, None] >> shifts) & jnp.uint32(1)
    flat = bits.reshape(*words.shape[:-1], words.shape[-1] * WORD)
    return flat[..., :n_bits].astype(jnp.uint8)


def pack_bits_np(bits: np.ndarray) -> np.ndarray:
    """Host-side pack: ``[..., L]`` 0/1 -> ``[..., ceil(L/32)] uint32``.

    Uses ``np.packbits(bitorder='little')`` + an explicit little-endian
    ``uint32`` view, so the layout matches :func:`pack_bits` bit-for-bit
    on any host.
    """
    bits = np.asarray(bits, dtype=np.uint8)
    nw = words_for(bits.shape[-1])
    by = np.packbits(bits, axis=-1, bitorder="little")   # [..., ceil(L/8)]
    pad = nw * 4 - by.shape[-1]
    if pad:
        pads = [(0, 0)] * (by.ndim - 1) + [(0, pad)]
        by = np.pad(by, pads)
    return np.ascontiguousarray(by).view("<u4")


def unpack_words_f32(words: jax.Array, *, n_bits: int) -> jax.Array:
    """In-kernel unpack: ``[bt, kw] uint32`` -> ``[bt, n_bits] f32``.

    ``n_bits`` must equal ``32 * kw``.  Written with ``jnp.repeat`` +
    ``broadcasted_iota`` (>= 2D, per the TPU iota constraint) so it
    lowers inside a Pallas kernel body; the expansion lives entirely in
    VMEM/registers — HBM only ever sees the words.
    """
    bt, kw = words.shape
    if n_bits != kw * WORD:
        raise ValueError(f"n_bits={n_bits} != {kw}*{WORD}")
    expanded = jnp.repeat(words, WORD, axis=1)                 # [bt, n_bits]
    shift = jax.lax.broadcasted_iota(jnp.uint32, (bt, n_bits), 1) % WORD
    return ((expanded >> shift) & jnp.uint32(1)).astype(jnp.float32)


def unpack_words_f32_cols(words: jax.Array, *, n_bits: int) -> jax.Array:
    """In-kernel unpack along axis 0: ``[kw, ct] uint32`` ->
    ``[n_bits, ct] f32``.

    ``n_bits`` must equal ``32 * kw``.  Bit ``j`` of word row ``w``
    becomes row ``32*w + j`` — the transposed ``[L, C]`` plane layout of
    the analog kernels' conductance operands, so the plane-packed
    kernels can reconstruct ``g``/``leak`` tiles in VMEM from an index
    bitplane that is 32x smaller in HBM.
    """
    kw, ct = words.shape
    if n_bits != kw * WORD:
        raise ValueError(f"n_bits={n_bits} != {kw}*{WORD}")
    expanded = jnp.repeat(words, WORD, axis=0)                 # [n_bits, ct]
    shift = jax.lax.broadcasted_iota(jnp.uint32, (n_bits, ct), 0) % WORD
    return ((expanded >> shift) & jnp.uint32(1)).astype(jnp.float32)
